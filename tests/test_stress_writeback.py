"""Concurrency stress for the WritebackPool + window nonblocking layer.

Many threads race ``rput`` / ``flush_async`` / ``flush_all`` against one
storage window.  Invariants under fire:

* per-target-rank FIFO: each thread's writes to its private region land in
  issue order, so the *last* value wins;
* no write is ever lost: after the final drain the backing files match the
  expected bytes byte-for-byte;
* with backpressure enabled, queued in-flight bytes never exceed the high
  watermark (the pool records the observed high-water mark at submit time).

Marked ``slow``: quick runs exclude these with ``-m 'not slow'``.
"""

import threading

import numpy as np
import pytest

from repro.core import Communicator, Request, Window
from repro.core.storage import WritebackPool

NRANKS = 4
THREADS = 8
WRITES = 120
REGION = 512  # bytes, per-thread private region
PAGES_PER_RANK = 8


def _storage_info(tmp_path):
    return {"alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / "stress.bin")}


def _run_race(win, *, probe_order: bool):
    """THREADS writers race rputs + async flushes; returns per-thread errors."""
    errs = []
    start = threading.Barrier(THREADS)

    def worker(t):
        try:
            rank = t % NRANKS
            base = (t // NRANKS) * REGION
            start.wait()
            last = None
            for seq in range(WRITES):
                val = (t * WRITES + seq) % 251
                last = val
                win.rput(np.full(REGION, val, np.uint8), rank, base)
                if seq % 16 == 15:
                    win.flush_async(rank)
                if seq % 48 == 47:
                    win.flush_all()
            if probe_order:
                # FIFO per rank: a get issued after all rputs must observe
                # the final value
                got = win.rget(rank, base, REGION).wait(timeout=30.0)
                assert (got == last).all(), "FIFO violated mid-flight"
        except BaseException as e:  # pragma: no cover - surfaced below
            errs.append((t, e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(THREADS)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return errs


def _expected_region(t):
    return np.full(REGION, ((t + 1) * WRITES - 1) % 251, np.uint8)


def _verify_files(tmp_path):
    """Final file contents byte-for-byte: every thread's last write won."""
    for t in range(THREADS):
        rank = t % NRANKS
        base = (t // NRANKS) * REGION
        raw = np.fromfile(f"{tmp_path / 'stress.bin'}.{rank}", np.uint8)
        got = raw[base: base + REGION]
        want = _expected_region(t)
        assert (got == want).all(), \
            f"thread {t} rank {rank}: lost/reordered write"


@pytest.mark.slow
def test_stress_racing_rput_flush_fifo_no_lost_writes(tmp_path):
    comm = Communicator(NRANKS)
    win = Window.allocate(comm, PAGES_PER_RANK * 4096,
                          info=_storage_info(tmp_path), async_workers=4)
    errs = _run_race(win, probe_order=True)
    assert not errs, errs
    win.flush_all()
    win.sync()  # persist whatever the async flushes didn't catch
    win.free()
    _verify_files(tmp_path)


@pytest.mark.slow
def test_stress_backpressure_bounds_inflight_bytes(tmp_path):
    high, low = 64 << 10, 16 << 10
    comm = Communicator(NRANKS)
    win = Window.allocate(comm, PAGES_PER_RANK * 4096,
                          info=_storage_info(tmp_path), async_workers=4,
                          max_inflight_bytes=high, low_watermark=low)
    errs = _run_race(win, probe_order=False)
    assert not errs, errs
    win.flush_all()
    stats = win.pool_stats()
    win.sync()
    win.free()
    _verify_files(tmp_path)
    # every payload (REGION) is far below high-low, so the bound is strict
    assert stats["max_inflight_bytes"] <= high, stats
    assert stats["submitted_bytes"] == stats["completed_bytes"]
    assert stats["inflight_bytes"] == 0


@pytest.mark.slow
def test_stress_pool_fifo_per_key_many_keys():
    """Pool-level FIFO: per-key sequence numbers must arrive in order even
    with more keys than workers and concurrent submitters."""
    pool = WritebackPool(3)
    seen: dict[int, list[int]] = {k: [] for k in range(6)}
    seen_lock = threading.Lock()

    def make(k, s):
        def task():
            with seen_lock:
                seen[k].append(s)
        return task

    def submitter(k):
        for s in range(300):
            pool.submit(make(k, s), key=k)

    threads = [threading.Thread(target=submitter, args=(k,))
               for k in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    pool.drain()
    pool.shutdown()
    for k, lst in seen.items():
        assert lst == sorted(lst), f"key {k} executed out of order"
        assert len(lst) == 300


def test_backpressure_submit_blocks_until_drained():
    """Quick (non-slow) watermark unit test: a submit past the high mark
    stalls until completions drain to the low mark."""
    gate = threading.Event()
    pool = WritebackPool(1, max_inflight_bytes=2048, low_watermark=512)
    pool.submit(gate.wait, nbytes=1024)  # occupies the single worker
    pool.submit(lambda: None, nbytes=1024)  # fits exactly at the high mark

    admitted = threading.Event()

    def late():
        pool.submit(lambda: None, nbytes=512)  # must stall: 2048 in flight
        admitted.set()

    th = threading.Thread(target=late)
    th.start()
    assert not admitted.wait(0.3), "submit should stall past the high mark"
    gate.set()  # drain: both queued tasks complete -> 0 <= low watermark
    assert admitted.wait(10.0), "stalled submit never resumed"
    th.join()
    pool.drain()
    stats = pool.stats()
    pool.shutdown()
    assert stats["stalls"] == 1
    assert stats["max_inflight_bytes"] <= 2048


def test_backpressure_oversized_task_admitted_alone():
    """A single submission larger than the high mark must not deadlock: it
    is admitted once the queue is empty."""
    pool = WritebackPool(1, max_inflight_bytes=1024)
    pool.submit(lambda: None, nbytes=512)
    t = pool.submit(lambda: None, nbytes=4096)  # > high mark
    assert t.wait(10.0)
    pool.shutdown()
    assert pool.stats()["completed"] == 2
