"""Checkpoint manager + windowed pytrees + out-of-core optimizer."""

import json
import os

import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import Communicator, WindowedPyTree, auto_factor
from repro.core.offload import WindowedArray
from repro.train import AdamWConfig, adamw_update, init_opt_state
from repro.train.offload_opt import OutOfCoreAdamW


def test_auto_factor():
    assert auto_factor(100, 1000) == 1.0
    assert auto_factor(2000, 1000) == 0.5
    assert auto_factor(0, 10) == 1.0


def test_windowed_pytree_roundtrip(tmp_path):
    comm = Communicator(1)
    tree = {"a": np.arange(100, dtype=np.float32).reshape(10, 10),
            "b": np.arange(7, dtype=np.int32)}
    wt = WindowedPyTree.from_tree(comm, tree, info={
        "alloc_type": "storage",
        "storage_alloc_filename": str(tmp_path / "t.bin")})
    got = wt.get_tree()
    for k in tree:
        assert (got[k] == tree[k]).all()
    # deterministic layout: manifest reconstructs identical offsets
    m = wt.manifest()
    slots = WindowedPyTree.slots_from_manifest(m)
    assert slots["a"].offset == wt.slots["a"].offset
    wt.free()


def test_windowed_array_blockwise(tmp_path):
    comm = Communicator(1)
    wt = WindowedPyTree.allocate(comm, {"x": ((1000,), np.float32)}, info={
        "alloc_type": "storage",
        "storage_alloc_filename": str(tmp_path / "b.bin")}, block_bytes=256)
    wa = wt.array("x")
    wa.put(np.arange(1000, dtype=np.float32))
    assert wa.num_blocks == int(np.ceil(4000 / 256))
    wa.update_blocks(lambda b: b * 2)  # streamed out-of-core transform
    assert (wa.get() == np.arange(1000) * 2).all()
    wt.free()


def test_ckpt_save_restore_and_double_buffer(tmp_path):
    comm = Communicator(1)
    specs = {"w": ((8, 8), np.float32), "s": ((), np.int32)}
    cm = CheckpointManager(str(tmp_path), comm, specs)
    t1 = {"w": np.ones((8, 8), np.float32), "s": np.int32(1)}
    t2 = {"w": np.full((8, 8), 2.0, np.float32), "s": np.int32(2)}
    cm.save(1, t1)
    cm.save(2, t2)
    r = cm.restore()
    assert r.step == 2 and (r.tree["w"] == 2).all()
    # torn write: corrupt the latest target ON DISK, then restart cold --
    # the fresh manager must CRC-fail the newest manifest and fall back.
    with open(cm._manifest_path()) as f:
        target = json.load(f)["target"]
    with open(os.path.join(str(tmp_path), f"ckpt_{target}.bin"), "r+b") as f:
        f.seek(0)
        f.write(b"\xde\xad\xbe\xef" * 8)
    cm2 = CheckpointManager.open_for_restore(str(tmp_path), Communicator(1),
                                             specs)
    r2 = cm2.restore()
    assert r2 is not None and r2.fell_back and r2.step == 1
    assert (r2.tree["w"] == 1).all()
    cm2.close()


def test_ckpt_selective_sync(tmp_path):
    comm = Communicator(1)
    specs = {"big": ((1 << 16,), np.float32), "tiny": ((4,), np.float32)}
    cm = CheckpointManager(str(tmp_path), comm, specs, double_buffer=False)
    big = np.random.default_rng(0).standard_normal(1 << 16).astype(np.float32)
    f1 = cm.save(1, {"big": big, "tiny": np.zeros(4, np.float32)})
    # change only the tiny slot: selective sync flushes ~1 page, not 256 KiB
    f2 = cm.save(2, {"big": big, "tiny": np.ones(4, np.float32)})
    assert f2 <= 8192 < f1
    cm.close()


def test_ckpt_async_overlap(tmp_path):
    comm = Communicator(1)
    specs = {"w": ((256, 256), np.float32)}
    cm = CheckpointManager(str(tmp_path), comm, specs)
    cm.save_async(1, {"w": np.ones((256, 256), np.float32)})
    cm.wait()
    r = cm.restore()
    assert r.step == 1
    cm.close()


def test_crash_restart_reopens_files(tmp_path):
    comm = Communicator(1)
    specs = {"w": ((16,), np.float32)}
    cm = CheckpointManager(str(tmp_path), comm, specs)
    cm.save(5, {"w": np.full(16, 5.0, np.float32)})
    del cm  # "crash": no close
    cm2 = CheckpointManager.open_for_restore(str(tmp_path), Communicator(1), specs)
    r = cm2.restore()
    assert r.step == 5 and (r.tree["w"] == 5).all()
    cm2.close()


def test_out_of_core_adamw_matches_fused(tmp_path):
    """OutOfCoreAdamW (storage windows) == on-device AdamW, bit-for-bit-ish."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    params = {"w": rng.standard_normal((32, 16)).astype(np.float32),
              "b": rng.standard_normal(16).astype(np.float32)}
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                      clip_norm=0.0, weight_decay=0.01)
    # fused reference
    p_ref = {k: jnp.asarray(v) for k, v in params.items()}
    st = init_opt_state(p_ref)
    oo = OutOfCoreAdamW(Communicator(1),
                        {k: (v.shape, v.dtype) for k, v in params.items()},
                        str(tmp_path), cfg, block_bytes=256)
    oo.initialize(params)
    for step in range(3):
        grads = {k: rng.standard_normal(v.shape).astype(np.float32)
                 for k, v in params.items()}
        p_ref, st, _ = adamw_update(p_ref, {k: jnp.asarray(g)
                                            for k, g in grads.items()}, st, cfg)
        oo.update(grads)
    masters = oo.masters()
    for k in params:
        np.testing.assert_allclose(masters[k], np.asarray(p_ref[k]),
                                   rtol=2e-5, atol=2e-6)
    assert oo.sync() >= 0
    oo.free()
