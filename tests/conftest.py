import os

# tests run on the single default host device -- the dry-run (and only the
# dry-run) forces 512 devices in its own subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_file(tmp_path):
    return str(tmp_path / "backing.bin")
