import os

# tests run on the single default host device -- the dry-run (and only the
# dry-run) forces 512 devices in its own subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
import types

import numpy as np
import pytest


def _install_hypothesis_stub() -> None:
    """Let the suite collect and run without hypothesis installed.

    Six test modules import ``hypothesis`` at module scope, which used to
    abort collection of the whole module (taking every plain test in it
    down too).  This shim registers a stub ``hypothesis`` package whose
    ``@given`` replaces the test with a graceful skip -- the importorskip
    analogue, but per-test instead of per-module, so non-property tests in
    those modules still run.  Install the real thing via
    ``requirements-dev.txt`` (or ``scripts/tier1.sh``) to run the property
    tests.
    """

    class _Strategy:
        def __init__(self, name: str = "strategy"):
            self._name = name

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):  # .map/.filter/.flatmap chains
            return _Strategy(f"{self._name}.{name}")

        def __repr__(self):
            return f"<hypothesis-stub strategy {self._name}>"

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.__getattr__ = lambda name: _Strategy(name)  # PEP 562

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(request):
                pytest.skip("hypothesis is not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = getattr(fn, "__name__", "test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            return skipper
        return deco

    class settings:  # used both as @settings(...) and settings(...) object
        def __init__(self, *_args, **_kwargs):
            pass

        def __call__(self, fn):
            return fn

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda _cond=True: True
    hyp.note = lambda _msg: None
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    hyp.strategies = st_mod
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised when dev deps missing
    _install_hypothesis_stub()
else:
    # CI-reproducible property testing: the "ci" profile disables deadlines
    # (CI boxes stall unpredictably) and derandomizes, so tier1.sh runs the
    # same example sequence every time; "dev" keeps randomized exploration.
    # Select with HYPOTHESIS_PROFILE (default: ci).
    from hypothesis import HealthCheck, settings as _hsettings

    _hsettings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=60,
        suppress_health_check=[HealthCheck.too_slow])
    _hsettings.register_profile("dev", deadline=None, max_examples=100)
    _hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running stress tests (quick runs: -m 'not slow')")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_file(tmp_path):
    return str(tmp_path / "backing.bin")
