"""SPMD origin conformance (the rank-symmetric contract).

Three guarantees, each load-bearing for the multi-origin refactor:

* **Parity**: the same checkpoint workload run driver-origin (inproc,
  rank-0 identity) and SPMD (every rank its own origin) leaves
  byte-identical rank-0 window files and an identical ``manifest.json``
  -- and the SPMD ranks' extra partitions restore under *driver-style*
  rank-local communicators, so a crashed SPMD job recovers under either
  bootstrap mode.
* **Accounting**: under SPMD each rank issues its own data-path
  operations (local puts observed per rank) while the launcher issues
  zero -- the driver really did shrink to a launcher/monitor.
* **Resilience**: SIGKILL one SPMD rank mid-run; ``rebuild_rank``
  re-enters the application function on the respawn, which restores from
  its own manifest and resumes exactly (no step replayed from scratch).

Workload functions are module-level so the spawn start method can pickle
them by reference.
"""

from __future__ import annotations

import os
import signal
import time

import numpy as np
import pytest

from repro.core import Communicator

try:
    import multiprocessing.shared_memory  # noqa: F401
    HAVE_SHM = True
except ImportError:  # pragma: no cover - exotic platforms
    HAVE_SHM = False

pytestmark = pytest.mark.skipif(
    not HAVE_SHM, reason="multiprocessing.shared_memory unavailable")

_N = 3
_STEPS = (1, 2, 3)
_SPECS = {"w": ((64,), np.float32), "b": ((8,), np.float32)}


def _tree(rank: int, step: int) -> dict[str, np.ndarray]:
    """Deterministic per-(rank, step) state: parity must come from the
    machinery, not from luck with rng seeding."""
    return {"w": np.arange(64, dtype=np.float32) + 100.0 * rank + step,
            "b": np.full(8, 10.0 * rank + step, np.float32)}


def _parity_workload(comm: Communicator, directory: str) -> dict:
    from repro.ckpt import CheckpointManager
    mgr = CheckpointManager(directory, comm, _SPECS)
    for step in _STEPS:
        mgr.save(step, _tree(comm.rank, step))
    mgr.close()
    snap = getattr(comm.transport, "stats_snapshot", None)
    return {"rank": comm.rank, "stats": snap() if snap else None}


def _resume_workload(comm: Communicator, directory: str,
                     steps: int = 8) -> dict:
    from repro.ckpt import CheckpointManager
    mgr = CheckpointManager(directory, comm, _SPECS)
    res = mgr.restore()
    start = res.step if res is not None else 0
    for step in range(start + 1, steps + 1):
        mgr.save(step, _tree(comm.rank, step))
        time.sleep(0.15)  # give the driver a window to SIGKILL mid-run
    mgr.close()
    return {"rank": comm.rank, "resumed_from": start}


def _run_spmd(workload, directory: str, **kw):
    from repro.core.transport.spmd import SpmdLauncher
    launcher = SpmdLauncher(_N, workload, (directory,))
    try:
        results = launcher.wait(timeout=120)
        return launcher, sorted(results, key=lambda r: r["rank"])
    finally:
        launcher.shutdown()


@pytest.fixture(scope="module")
def spmd_parity(tmp_path_factory):
    """One SPMD parity run shared by the parity + accounting tests."""
    d = str(tmp_path_factory.mktemp("spmd"))
    launcher, results = _run_spmd(_parity_workload, d)
    return d, launcher, results


def test_parity_with_driver_origin(spmd_parity, tmp_path):
    d_spmd, _, _ = spmd_parity
    d_drv = str(tmp_path / "drv")
    comm = Communicator(_N, transport="inproc")
    _parity_workload(comm, d_drv)
    comm.close()

    # rank 0's window files: byte-identical across origin modes
    for name in ("ckpt_a.bin.0", "ckpt_b.bin.0"):
        a = open(os.path.join(d_drv, name), "rb").read()
        b = open(os.path.join(d_spmd, name), "rb").read()
        assert a == b, f"{name} differs between driver-origin and SPMD"
    # and the committed manifests match exactly (step, target, layout,
    # crc, nranks -- nothing in them may depend on who issued the ops)
    for name in ("manifest.json", "manifest.prev.json"):
        a = open(os.path.join(d_drv, name)).read()
        b = open(os.path.join(d_spmd, name)).read()
        assert a == b, f"{name} differs between driver-origin and SPMD"
    # SPMD ranks > 0 commit their own manifests beside rank 0's
    for r in range(1, _N):
        assert os.path.exists(os.path.join(d_spmd, f"manifest.r{r}.json"))


def test_spmd_partitions_restore_under_driver_mode(spmd_parity):
    """Cross-mode recovery: every SPMD rank's checkpoint restores under a
    driver-style rank-local communicator reading the same directory."""
    from repro.ckpt import CheckpointManager
    d_spmd, _, _ = spmd_parity
    last = _STEPS[-1]
    for r in range(_N):
        comm = Communicator(_N, rank=r,
                            transport="inproc" if r == 0 else "ranklocal")
        mgr = CheckpointManager(d_spmd, comm, _SPECS)
        res = mgr.restore()
        assert res is not None and res.step == last
        want = _tree(r, last)
        for k in _SPECS:
            np.testing.assert_array_equal(res.tree[k], want[k])
        mgr.close()
        comm.close()


def test_per_rank_accounting(spmd_parity):
    """Each rank is a real origin: its own data-path ops, its own window
    partition -- and the launcher issued zero data-path operations."""
    _, launcher, results = spmd_parity
    assert [r["rank"] for r in results] == list(range(_N))
    for r in results:
        stats = r["stats"]
        assert stats is not None
        # every rank allocated and wrote its own partition locally
        assert stats["local"]["alloc"] > 0
        assert stats["local"]["put"] > 0
        # and took part in the collective rounds (alloc gather, barriers)
        assert stats["rounds"] > 0
    assert launcher.data_ops() == 0
    assert set(launcher.op_counts) <= {"ping", "shutdown"}


def test_kill_one_rank_resumes_exactly(tmp_path):
    from repro.core.transport.spmd import SpmdLauncher
    d = str(tmp_path / "resume")
    os.makedirs(d)
    launcher = SpmdLauncher(_N, _resume_workload, (d,))
    victim = 1
    try:
        # wait for the victim to commit at least one manifest, then kill
        marker = os.path.join(d, f"manifest.r{victim}.json")
        deadline = time.monotonic() + 60
        while not os.path.exists(marker):
            assert time.monotonic() < deadline, "victim never checkpointed"
            time.sleep(0.05)
        os.kill(launcher._procs[victim].pid, signal.SIGKILL)
        deadline = time.monotonic() + 30
        while launcher.probe(victim):
            assert time.monotonic() < deadline, "victim still probes live"
            time.sleep(0.05)
        launcher.rebuild_rank(victim)
        results = sorted(launcher.wait(timeout=120),
                         key=lambda r: r["rank"])
        # the respawn re-entered the application, restored its own
        # manifest, and resumed from a nonzero step
        assert results[victim]["resumed_from"] > 0
        # survivors never restarted
        for r in range(_N):
            if r != victim:
                assert results[r]["resumed_from"] == 0
        assert launcher.data_ops() == 0
    finally:
        launcher.shutdown()
