"""Transport conformance: the same window semantics over every backend.

Every test in the parametrized half runs once per backend -- the
in-process transport, the multiprocess transport (4 real worker
processes), and the tcp transport (4 worker processes reached over real
loopback sockets) -- and must observe identical behavior: that is the
contract that lets every higher layer (DHT, MapReduce, checkpoints)
ignore where ranks live.

The backend-specific halves cover what only real processes can show:
shared-memory windows (mp), worker-kill fault tolerance with recovery
from the storage window, unreachable-rank errors, and cross-backend
crash/recovery over the byte-identical file layout (tcp -> mp).
"""

import socket

import numpy as np
import pytest

from repro.core import (Communicator, DistributedHashTable, MapReduce1S,
                        TransportError, Window)
from repro.core.mapreduce import wordcount_map

try:
    import multiprocessing.shared_memory  # noqa: F401
    HAVE_SHM = True
except ImportError:  # pragma: no cover - exotic platforms
    HAVE_SHM = False


def _loopback_ok() -> bool:
    try:
        srv = socket.create_server(("127.0.0.1", 0))
        srv.close()
        return True
    except OSError:  # pragma: no cover - sandboxed/socket-less platforms
        return False


HAVE_LOOPBACK = _loopback_ok()

BACKENDS = ["inproc", "mp", "tcp"]


def _skip_if_unavailable(kind: str) -> None:
    if kind == "mp" and not HAVE_SHM:
        pytest.skip("multiprocessing.shared_memory unavailable")
    if kind == "tcp" and not HAVE_LOOPBACK:
        pytest.skip("loopback sockets unavailable")


@pytest.fixture(scope="module", params=BACKENDS)
def comm4(request):
    """One 4-rank communicator per backend, shared by the module (spawning
    worker processes per test would dominate the suite's runtime)."""
    _skip_if_unavailable(request.param)
    comm = Communicator(4, transport=request.param)
    yield comm
    comm.close()


def storage_info(tmp_path, name="w.bin"):
    return {"alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / name)}


# -- one-sided conformance ----------------------------------------------------

def test_memory_window_put_get(comm4):
    with Window.allocate(comm4, 1024) as win:
        for r in range(comm4.size):
            win.put(np.full(16, r + 1, np.uint8), r, 8 * r)
        for r in range(comm4.size):
            assert (win.get(r, 8 * r, 16) == r + 1).all()


def test_storage_window_put_get_sync(comm4, tmp_path):
    with Window.allocate(comm4, 8192, info=storage_info(tmp_path)) as win:
        data = np.arange(256, dtype=np.int64)
        win.put(data.view(np.uint8), 3, 64)
        assert (win.get(3, 64, 256, np.int64) == data).all()
        assert win.dirty_bytes(3) > 0
        flushed = win.sync(3)
        assert flushed > 0
        assert win.dirty_bytes(3) == 0
        assert win.sync(3) == 0  # already synchronized
    # durability: the bytes are on disk under the per-rank naming scheme
    raw = np.fromfile(str(tmp_path / "w.bin.3"), dtype=np.uint8)
    assert (raw[64:64 + 256 * 8].view(np.int64) == data).all()


def test_accumulate_parity(comm4):
    ops = ["sum", "prod", "min", "max", "band", "bor", "replace"]
    expect = {"sum": np.add, "prod": np.multiply, "min": np.minimum,
              "max": np.maximum, "band": np.bitwise_and,
              "bor": np.bitwise_or}
    for op in ops:
        with Window.allocate(comm4, 64) as win:
            init = np.array([12], np.int64)
            win.put(init.view(np.uint8), 2, 0)
            val = np.array([7], np.int64)
            win.accumulate(val, 2, 0, op=op)
            got = win.get(2, 0, 1, np.int64)[0]
            want = val[0] if op == "replace" else expect[op](init, val)[0]
            assert got == want, op


def test_get_accumulate_and_fetch_op(comm4):
    with Window.allocate(comm4, 64) as win:
        win.put(np.array([100], np.int64).view(np.uint8), 0, 0)
        old = win.get_accumulate(np.array([5], np.int64), 0, 0, "sum")
        assert old[0] == 100
        assert win.fetch_and_op(1, 0, 0, "sum") == 105
        assert win.get(0, 0, 1, np.int64)[0] == 106


def test_compare_and_swap(comm4):
    with Window.allocate(comm4, 64) as win:
        win.put(np.array([-1], np.int64).view(np.uint8), 3, 0)
        assert win.compare_and_swap(10, -1, 3, 0) == -1   # swaps
        assert win.compare_and_swap(20, -1, 3, 0) == 10   # refuses
        assert win.get(3, 0, 1, np.int64)[0] == 10


def test_rput_rget_flush_pipeline(comm4, tmp_path):
    with Window.allocate(comm4, 4096, info=storage_info(tmp_path)) as win:
        reqs = [win.rput(np.full(64, r + 1, np.uint8), r, 0)
                for r in range(comm4.size)]
        for r in reqs:
            r.wait()
        got = [win.rget(r, 0, 64).wait() for r in range(comm4.size)]
        for r, g in enumerate(got):
            assert (g == r + 1).all()
        assert win.flush_async(2).wait() > 0


# -- collectives --------------------------------------------------------------

def test_barrier_ordering(comm4):
    """Operations issued before a barrier are visible after it completes on
    every rank (channel-FIFO completion under mp)."""
    before = comm4.barrier_count
    with Window.allocate(comm4, 64) as win:
        for r in range(comm4.size):
            win.put(np.full(8, 42, np.uint8), r, 0)
        comm4.barrier()
        for r in range(comm4.size):
            assert (win.get(r, 0, 8) == 42).all()
    assert comm4.barrier_count >= before + 1


def test_allreduce_parity(comm4):
    vals = [1.5, -2.0, 7.25, 3.0]
    assert comm4.allreduce(vals, "sum") == pytest.approx(9.75)
    assert comm4.allreduce(vals, "max") == pytest.approx(7.25)
    assert comm4.allreduce(vals, "min") == pytest.approx(-2.0)
    # array-valued contributions
    mat = [np.full(3, r, np.int64) for r in range(comm4.size)]
    np.testing.assert_array_equal(comm4.allreduce(mat, "sum"),
                                  np.full(3, 6, np.int64))
    # already-reduced (non-list) input passes through
    assert comm4.allreduce(5.0) == 5.0


def test_allreduce_wrong_length_raises(comm4):
    with pytest.raises(ValueError, match="contribution per rank"):
        comm4.allreduce([1, 2], "sum")
    with pytest.raises(ValueError, match="contribution per rank"):
        comm4.allreduce(list(range(comm4.size + 1)), "sum")


def test_bcast(comm4):
    assert comm4.bcast(42) == 42
    assert comm4.bcast({"k": [1, 2, 3]}, root=2) == {"k": [1, 2, 3]}
    with pytest.raises(ValueError):
        comm4.bcast(1, root=comm4.size)


def test_split_translated_ranks(comm4):
    sub = comm4.split(color=1, ranks=[1, 3])
    assert sub.size == 2
    assert sub.color == 1
    assert sub.parent_ranks == (1, 3)
    assert sub.translate_rank(0) == 1 and sub.translate_rank(1) == 3
    assert sub.group_rank(3) == 1 and sub.group_rank(0) is None
    # the sub-communicator is fully functional and has its own registry
    with Window.allocate(sub, 128) as win:
        assert sub.active_windows() == 1
        assert comm4.active_windows() == 0
        win.put(np.full(4, 9, np.uint8), 1, 0)
        assert (win.get(1, 0, 4) == 9).all()
    assert sub.allreduce([10, 20], "sum") == 30
    # nested split translates to the root communicator
    subsub = sub.split(color=0, ranks=[1])
    assert subsub.parent_ranks == (3,)
    sub.close()


def test_split_validates_ranks(comm4):
    with pytest.raises(ValueError):
        comm4.split(0, [])
    with pytest.raises(ValueError):
        comm4.split(0, [0, 0])
    with pytest.raises(ValueError):
        comm4.split(0, [0, comm4.size])


# -- applications behave identically across backends --------------------------

def _dht_fill(comm, tmp_path):
    dht = DistributedHashTable(comm, 128, info=storage_info(tmp_path, "dht.bin"))
    rng = np.random.default_rng(7)
    for k in rng.integers(1, 1 << 40, 200):
        dht.insert(int(k), 1, op="sum")
    items = sorted(dht.items())
    dht.free()
    return items


def test_dht_results_match_reference(comm4, tmp_path):
    """The DHT contents depend only on keys/hashing, never on the backend:
    compare against a freshly computed in-process reference."""
    ref_comm = Communicator(4, transport="inproc")  # pinned reference
    ref = _dht_fill(ref_comm, tmp_path / "ref")
    ref_comm.close()
    got = _dht_fill(comm4, tmp_path / "run")
    assert got == ref


def test_mapreduce_results_match_reference(comm4, tmp_path):
    rng = np.random.default_rng(3)
    words = "alpha beta gamma delta epsilon zeta".split()
    tasks = [" ".join(rng.choice(words, 60)) for _ in range(8)]
    expect = {}
    for t in tasks:
        for k, v in wordcount_map(t).items():
            expect[k] = expect.get(k, 0) + v
    mr = MapReduce1S(comm4, 1 << 8, info=storage_info(tmp_path, "mr.bin"))
    mr.run(tasks)
    assert mr.result() == expect
    assert mr.completed_tasks() == len(tasks)
    mr.free()


# -- masked selective sync: same bytes flushed over both backends -------------

PAGE = 4096


def _page_mask(*blocks, n=16):
    m = np.zeros(n, dtype=bool)
    for b in blocks:
        m[b] = True
    return m


def _masked_sync_case(comm, base, *, blocking):
    """Dirty pages 1/3/5 of rank 2, flush {3,7} masked, then the rest."""
    win = Window.allocate(comm, 16 * PAGE, info=storage_info(base, "m.bin"))
    try:
        for pg in (1, 3, 5):
            win.put(np.full(32, pg + 1, np.uint8), 2, pg * PAGE)
        if blocking:
            masked = win.sync(2, mask=_page_mask(3, 7))
        else:
            masked = win.flush_async(2, mask=_page_mask(3, 7)).wait(
                timeout=30.0)
        rest = win.sync(2)
        disk = np.fromfile(str(base / "m.bin.2"), np.uint8)
        return masked, rest, int(disk[3 * PAGE]), int(disk[5 * PAGE])
    finally:
        win.free()


@pytest.mark.parametrize("blocking", [True, False], ids=["sync", "flush_async"])
def test_masked_sync_bytes_parity(comm4, tmp_path, blocking):
    """sync(mask=)/flush_async(mask=) flush the same intersection bytes on
    every backend: the owner's DirtyTracker does the narrowing, wherever
    the page cache lives."""
    ref_comm = Communicator(4, transport="inproc")  # pinned reference
    ref = _masked_sync_case(ref_comm, tmp_path / "ref", blocking=blocking)
    ref_comm.close()
    got = _masked_sync_case(comm4, tmp_path / "run", blocking=blocking)
    assert got == ref == (PAGE, 2 * PAGE, 4, 6)


def test_mask_length_validated_on_both_backends(comm4, tmp_path):
    from repro.core import WindowError
    with Window.allocate(comm4, 16 * PAGE,
                         info=storage_info(tmp_path, "v.bin")) as win:
        with pytest.raises(WindowError, match="blocks"):
            win.sync(1, mask=np.ones(15, bool))  # short: would skip the tail
        with pytest.raises(WindowError, match="blocks"):
            win.flush_async(1, mask=np.ones(17, bool))


def _device_sync_case(comm, base, jnp, *, blocking):
    win = Window.allocate(comm, 16 * PAGE, info=storage_info(base, "d.bin"))
    try:
        elems = 16 * PAGE // 4
        snap = np.arange(elems, dtype=np.float32)
        win.put(snap, 1, 0)
        win.sync(1)
        cur = snap.copy()
        cur[(PAGE // 4) * 4 + 1] += 1.0   # page 4
        cur[(PAGE // 4) * 11] += 2.0      # page 11
        res = win.sync_from_device(1, jnp.asarray(cur), jnp.asarray(snap),
                                   blocking=blocking)
        flushed = res if blocking else res.wait(timeout=30.0)
        disk = np.fromfile(str(base / "d.bin.1"), np.float32)
        return flushed, bool((disk == cur).all()), win.dirty_bytes(1)
    finally:
        win.free()


@pytest.mark.parametrize("blocking", [True, False], ids=["sync", "flush_async"])
def test_sync_from_device_remote_owner_parity(comm4, tmp_path, blocking):
    """The device-mask pipeline is transport-native: changed spans + mask
    reach the owner's page cache and DirtyTracker wherever the rank lives,
    flushing exactly the changed pages on both backends."""
    jnp = pytest.importorskip("jax.numpy")
    ref_comm = Communicator(4, transport="inproc")
    ref = _device_sync_case(ref_comm, tmp_path / "ref", jnp,
                            blocking=blocking)
    ref_comm.close()
    got = _device_sync_case(comm4, tmp_path / "run", jnp, blocking=blocking)
    assert got == ref == (2 * PAGE, True, 0)


def test_sync_from_device_one_round_trip_mp(comm4, tmp_path):
    """Under mp the whole device-sync epilogue -- spans, mask, masked flush
    -- is a single ``wsync`` control-channel message to the target rank."""
    pytest.importorskip("jax.numpy")
    if comm4.transport.kind not in ("mp", "tcp"):
        pytest.skip("round-trip accounting needs a control channel")
    win = Window.allocate(comm4, 16 * PAGE, info=storage_info(tmp_path))
    try:
        elems = 16 * PAGE // 4
        snap = np.arange(elems, dtype=np.float32)
        win.put(snap, 3, 0)
        win.sync(3)
        cur = snap.copy()
        cur[0] += 1.0
        cur[-1] += 1.0
        ops = []
        orig_call = comm4.transport._call

        def counting_call(rank, msg):
            ops.append((rank, msg[0]))
            return orig_call(rank, msg)

        comm4.transport._call = counting_call
        try:
            assert win.sync_from_device(3, cur, snap, blocking=True) \
                == 2 * PAGE
        finally:
            comm4.transport._call = orig_call
        assert ops == [(3, "wsync")]  # one message carried everything
    finally:
        win.free()


def test_sync_shards_merged_mask_parity(comm4, tmp_path):
    """Two shard regions at different displacements merge into one mask
    and one flush; per-shard bytes land byte-exact on every backend."""
    jnp = pytest.importorskip("jax.numpy")

    def case(comm, base):
        win = Window.allocate(comm, 16 * PAGE,
                              info=storage_info(base, "s.bin"))
        try:
            a_snap = np.zeros(2 * PAGE // 4, np.float32)       # pages 0-1
            b_snap = np.ones(4 * PAGE // 4, np.float32)        # pages 8-11
            win.put(a_snap, 0, 0)
            win.put(b_snap, 0, 8 * PAGE)
            win.sync(0)
            a_cur = a_snap.copy()
            a_cur[3] = 7.0                                     # page 0
            b_cur = b_snap.copy()
            b_cur[-1] = -1.0                                   # page 11
            flushed = win.sync_shards_from_device(
                0, [(jnp.asarray(a_cur), jnp.asarray(a_snap), 0),
                    (jnp.asarray(b_cur), jnp.asarray(b_snap), 8 * PAGE)],
                blocking=True)
            disk = np.fromfile(str(base / "s.bin.0"), np.float32)
            return (flushed, float(disk[3]),
                    float(disk[12 * PAGE // 4 - 1]), win.dirty_bytes(0))
        finally:
            win.free()

    ref_comm = Communicator(4, transport="inproc")
    ref = case(ref_comm, tmp_path / "ref")
    ref_comm.close()
    got = case(comm4, tmp_path / "run")
    assert got == ref == (2 * PAGE, 7.0, -1.0, 0)


# -- multiprocess-only behavior ----------------------------------------------

needs_shm = pytest.mark.skipif(not HAVE_SHM,
                               reason="multiprocessing.shared_memory unavailable")


@needs_shm
def test_mp_memory_window_is_shared_memory():
    comm = Communicator(2, transport="mp")
    try:
        with Window.allocate(comm, 256) as win:
            # baseptr is a zero-copy view of the worker's shared mapping:
            # a direct store is visible through the one-sided interface
            view = win.baseptr(1)
            view[3] = 77
            assert win.get(1, 3, 1)[0] == 77
            # and the worker-side accumulate sees the driver's store
            win.accumulate(np.array([1], np.uint8), 1, 3, op="sum")
            assert view[3] == 78
            del view  # release the mapping before free() closes the shm
    finally:
        comm.close()


@needs_shm
def test_mp_dynamic_windows_rejected():
    comm = Communicator(2, transport="mp")
    try:
        with pytest.raises(Exception, match="in-process transport"):
            Window.create_dynamic(comm)
    finally:
        comm.close()


@needs_shm
def test_mp_worker_kill_detected_and_recovery(tmp_path):
    """Kill a rank's worker mid-run: operations against it fail loudly, its
    un-synced page cache is lost (the paper's failure model), and a fresh
    transport over the same storage-window files resumes from the last
    checkpoint -- replaying, never skipping, the unfinished tasks."""
    rng = np.random.default_rng(11)
    words = "one two three four five six seven".split()
    tasks = [" ".join(rng.choice(words, 50)) for _ in range(8)]
    expect = {}
    for t in tasks:
        for k, v in wordcount_map(t).items():
            expect[k] = expect.get(k, 0) + v

    comm = Communicator(4, transport="mp")
    mr = MapReduce1S(comm, 1 << 8, info=storage_info(tmp_path, "mr.bin"))
    # rank 0 commits two tasks (each commit checkpoints table + progress)
    my0 = mr._tasks_of(0, len(tasks))
    for pos in range(2):
        for k, v in wordcount_map(tasks[my0[pos]]).items():
            mr.table.insert(k, v, op="sum")
        mr._commit_task(0, pos)
    mr._drain_ckpt()  # the overlapped checkpoint is on storage
    done = mr.completed_tasks()
    assert done == 2

    # SIGKILL one worker: the process dies page cache and all
    victim = comm.transport._procs[1]
    victim.kill()
    victim.join(timeout=10)
    with pytest.raises(TransportError, match="unreachable"):
        mr.table.win.get(1, 0, 8)
    # cleanup must not leak the surviving workers: close() surfaces the
    # dead rank but still frees every other segment and stops the workers
    with pytest.raises(TransportError):
        comm.close()
    for p in comm.transport._procs:
        assert not p.is_alive()

    # restart: fresh workers over the same files resume at the first
    # unfinished task and the final result equals a clean run
    comm2 = Communicator(4, transport="mp")
    mr2 = MapReduce1S(comm2, 1 << 8, info=storage_info(tmp_path, "mr.bin"),
                      resume=True)
    assert mr2.completed_tasks() == done  # progress survived the kill
    mr2.run(tasks)
    assert mr2.result() == expect
    mr2.free()
    comm2.close()


@needs_shm
def test_mp_transport_env_bootstrap(monkeypatch):
    """Rank-symmetric contract: mp spawns a fresh worker world, so it is
    driver-only -- a worker rank (REPRO_RANK>0) must never spawn a second
    world.  Asking for mp from a nonzero rank raises; the worker instead
    bootstraps a rank-local view over its own partition."""
    monkeypatch.setenv("REPRO_TRANSPORT", "mp")
    monkeypatch.setenv("REPRO_NRANKS", "2")
    monkeypatch.setenv("REPRO_RANK", "1")
    with pytest.raises(ValueError, match="driver-only"):
        Communicator.from_env()
    monkeypatch.setenv("REPRO_TRANSPORT", "inproc")
    comm = Communicator.from_env()
    try:
        assert comm.transport.kind == "ranklocal"
        assert comm.size == 2
        assert comm.rank == 1
    finally:
        comm.close()


def test_rank_outside_size_rejected_at_bootstrap():
    with pytest.raises(ValueError, match="outside communicator"):
        Communicator(4, rank=5)
    with pytest.raises(ValueError, match="outside communicator"):
        Communicator(4, rank=-1)


def test_inproc_env_default(monkeypatch):
    monkeypatch.delenv("REPRO_TRANSPORT", raising=False)
    monkeypatch.delenv("REPRO_NRANKS", raising=False)
    comm = Communicator.from_env(3)
    assert comm.transport.kind == "inproc" and comm.size == 3
    comm.close()


@needs_shm
def test_mp_free_after_worker_death_idempotent(tmp_path):
    """free() after a rank's worker died surfaces the TransportError once;
    a second free() (and the communicator close) must not raise secondary
    errors -- teardown paths overlap in practice."""
    comm = Communicator(2, transport="mp")
    win = Window.allocate(comm, 4096, info=storage_info(tmp_path))
    win.put(np.full(16, 8, np.uint8), 1, 0)
    comm.transport._procs[1].kill()
    comm.transport._procs[1].join(timeout=10)
    with pytest.raises(TransportError):
        win.free()
    assert win.freed
    win.free()  # idempotent: the error does not replay
    assert comm.active_windows() == 0
    comm.close()  # no window left -> shuts the workers down cleanly
    for p in comm.transport._procs:
        assert not p.is_alive()


# -- request aggregation / notified access ------------------------------------

def test_batched_ops_fifo_parity(comm4, tmp_path):
    """An interleaved rput/raccumulate/rget train against one target keeps
    per-target FIFO order on both backends, byte-identical to a pinned
    in-process reference executing the same program op by op.  The rget in
    the middle must observe the pre-overwrite value (issue order, not
    completion batching, decides what a read sees)."""
    ref_comm = Communicator(4, transport="inproc")

    def program(comm, base, name):
        win = Window.allocate(comm, 4096,
                              info=storage_info(base, name))
        try:
            reqs = []
            reqs.append(win.rput(np.full(64, 1, np.uint8), 2, 0))
            reqs.append(win.raccumulate(np.full(8, 2, np.int64), 2, 0))
            mid = win.rget(2, 0, 64)  # sees put+acc, NOT the overwrite
            reqs.append(win.rput(np.full(64, 9, np.uint8), 2, 0))
            win.flush(2)
            mid_val = mid.wait()
            final = win.get(2, 0, 64)
            win.sync(2)
            disk = np.fromfile(str(base / f"{name}.2"),
                               dtype=np.uint8)[:64].copy()
            for r in reqs:
                r.wait()
            return mid_val, final, disk
        finally:
            win.free()

    got = program(comm4, tmp_path, "agg.bin")
    want = program(ref_comm, tmp_path, "ref.bin")
    ref_comm.close()
    for g, w in zip(got, want):
        assert (g == w).all()
    # the mid-train read really saw the accumulated (pre-overwrite) bytes:
    # 64 bytes of 0x01, each int64 lane bumped by the accumulate's +2
    assert (got[0].view(np.int64) == 0x0101010101010101 + 2).all()
    assert (got[1] == 9).all()


def test_batched_ops_one_round_trip_mp(comm4, tmp_path):
    """Round-trip accounting: N small rputs to one target cost exactly ONE
    posted control-channel message, and their flush ONE completion read --
    the aggregation + notified-access contract.  A train containing a get
    instead ships as exactly one replying ``opbatch``."""
    if comm4.transport.kind not in ("mp", "tcp"):
        pytest.skip("round-trip accounting needs a control channel")
    win = Window.allocate(comm4, 4096, info=storage_info(tmp_path))
    try:
        calls, posts = [], []
        orig_call, orig_post = comm4.transport._call, comm4.transport._post

        def counting_call(rank, msg):
            calls.append((rank, msg[0]))
            return orig_call(rank, msg)

        def counting_post(rank, msg):
            posts.append((rank, msg[0]))
            return orig_post(rank, msg)

        comm4.transport._call = counting_call
        comm4.transport._post = counting_post
        try:
            reqs = [win.rput(np.full(8, i, np.uint8), 3, 8 * i)
                    for i in range(32)]
            win.flush(3)
            assert all(r.test() for r in reqs)
            assert posts == [(3, "opbatch_nb")]  # one posted train
            assert calls == [(3, "notify_read")]  # one completion read
            calls.clear(), posts.clear()
            # a read in the train forces the replying form: one opbatch
            win.rput(np.full(8, 7, np.uint8), 3, 0)
            got = win.rget(3, 8, 8)
            assert (got.wait() == 1).all()
            assert posts == []
            assert calls == [(3, "opbatch")]
        finally:
            comm4.transport._call = orig_call
            comm4.transport._post = orig_post
        win.flush(3)
        assert (win.get(3, 0, 8) == 7).all()
    finally:
        win.free()


def test_batched_put_runs_coalesce_owner_side():
    """Adjacent puts in one train vectorize into a single segment write;
    an out-of-range straggler fails alone (slot-captured), never its valid
    neighbors -- sub-ops stay as independent as the MPI calls they batch."""
    from repro.core.transport.base import apply_op_batch
    from repro.core.transport.local import _MemorySegment

    class CountingSeg(_MemorySegment):
        def __init__(self, size):
            super().__init__(size)
            self.writes = 0

        def write(self, offset, data):
            self.writes += 1
            super().write(offset, data)

    seg = CountingSeg(256)
    ops = [("put", i * 8, np.full(8, i + 1, np.uint8)) for i in range(4)]
    ops.append(("put", 1024, np.ones(8, np.uint8)))  # out of range
    ops.append(("get", 0, 32))
    res = apply_op_batch(seg, ops)
    assert seg.writes == 2  # 4 adjacent puts -> 1 write (+1 failed retry)
    assert res[:4] == [None] * 4
    assert isinstance(res[4], IndexError)
    assert (res[5][:8] == 1).all() and (res[5][24:] == 4).all()


def test_notified_post_error_surfaces_at_flush(comm4, tmp_path):
    """A posted train completes optimistically (MPI local completion), so
    a target-side failure surfaces at the flush boundary's completion
    read -- the notified-access error contract -- and the window stays
    usable afterwards."""
    win = Window.allocate(comm4, 4096, info=storage_info(tmp_path))
    try:
        bad = win.rput(np.ones(16, np.uint8), 1, 4096)  # out of range
        ok = win.rput(np.full(8, 5, np.uint8), 1, 0)
        with pytest.raises(IndexError):
            win.flush(1)
        ok.wait(timeout=10.0)
        assert bad.test()
        assert (win.get(1, 0, 8) == 5).all()
    finally:
        win.free()


# -- transport metadata bugfix regressions ------------------------------------

def test_seg_meta_memory_reports_no_storage():
    """A tracker-less memory segment advertises sto_bytes=0 (it has no
    storage tier to sync); remote handles built from its meta must not
    report has_storage=True nor charge dirty-byte backpressure."""
    from repro.core.transport.local import _MemorySegment
    from repro.core.transport.multiproc import _RemoteSegment, _seg_meta

    meta = _seg_meta(_MemorySegment(256))
    assert meta["kind"] == "memory"
    assert meta["sto_bytes"] == 0

    seg = _RemoteSegment(None, 0, 1, meta)
    assert not seg.has_storage
    # satellite: write() must not grow the dirty estimate on a
    # memory-only segment -- there is no sync that could ever drain it
    class _FakeTransport:
        def _call(self, rank, msg):
            return None
    seg._t = _FakeTransport()
    seg.write(0, np.ones(64, np.uint8))
    assert seg.dirty_bytes_estimate() == 0


def test_seg_meta_storage_still_reports_size(tmp_path):
    from repro.core.hints import WindowHints
    from repro.core.transport.local import _make_segment
    from repro.core.transport.multiproc import _seg_meta

    hints = WindowHints.from_info(storage_info(tmp_path, "meta.bin"))
    seg = _make_segment(8192, hints, 0, 1, shared_file=False,
                        memory_budget=None, mechanism="cached",
                        page_size=4096, cache_bytes=None,
                        writeback_interval=None)
    try:
        meta = _seg_meta(seg)
        assert meta["kind"] == "storage"
        assert meta["sto_bytes"] == 8192
    finally:
        seg.close(unlink=True)


def test_service_sync_without_sync_method_raises_transport_error():
    """sync/wsync against a segment with no sync() must name the op and
    window kind in a TransportError, not leak an AttributeError."""
    from repro.core.transport.multiproc import _SegmentService

    class NoSync:
        kind = "memory"
        size = 64

        def write(self, offset, data):
            pass

    svc = _SegmentService(0)
    svc.segments[7] = NoSync()
    with pytest.raises(TransportError, match="'sync'.*memory window"):
        svc.execute(("sync", 7, False, None))
    with pytest.raises(TransportError, match="'wsync'.*memory window"):
        svc.execute(("wsync", 7, [], None))


# -- wire-stats plumbing (satellite: never None, never missing keys) ----------

def test_wire_stats_snapshot_well_formed_without_codec(tmp_path):
    """Backends with no codec policy (inproc) must still return the full
    all-zero counter schema -- from both Transport.wire_stats_snapshot and
    pool_stats()["wire"] -- so stats consumers never branch on backend."""
    from repro.core.codec import WireStats

    comm = Communicator(2, transport="inproc")
    try:
        assert comm.transport.codec_policy is None
        snap = comm.transport.wire_stats_snapshot()
        assert snap == WireStats().snapshot()
        assert snap["wire_bytes"] == 0 and snap["logical_bytes"] == 0
        win = Window.allocate(comm, 4 * PAGE,
                              info=storage_info(tmp_path, "ws.bin"))
        try:
            win.put(np.full(64, 3, np.uint8), 1, 0)
            assert win.flush_async(1).wait(timeout=30.0) > 0
            st = win.pool_stats()
            assert st is not None
            assert st["wire"] == WireStats().snapshot()
        finally:
            win.free()
    finally:
        comm.close()


# -- make_transport bootstrap errors (satellite) -------------------------------

def test_make_transport_unknown_kind_names_backends_and_env():
    from repro.core.transport import make_transport
    with pytest.raises(ValueError) as ei:
        make_transport(2, 0, "rdma")
    msg = str(ei.value)
    for kind in ("inproc", "mp", "ranklocal", "tcp"):
        assert kind in msg
    for var in ("REPRO_TRANSPORT", "REPRO_NRANKS", "REPRO_RANK",
                "REPRO_HOSTS"):
        assert var in msg


def test_tcp_worker_rank_requires_roster(monkeypatch):
    """tcp with REPRO_RANK>0 must join, never spawn: without a roster the
    error says exactly which env vars would provide one."""
    from repro.core.transport import make_transport
    monkeypatch.delenv("REPRO_HOSTS", raising=False)
    monkeypatch.delenv("REPRO_RENDEZVOUS", raising=False)
    with pytest.raises(ValueError, match="REPRO_HOSTS"):
        make_transport(2, 1, "tcp")


def test_env_hosts_parses_list_and_rendezvous_file(tmp_path, monkeypatch):
    from repro.core.transport import env_hosts
    monkeypatch.delenv("REPRO_HOSTS", raising=False)
    monkeypatch.delenv("REPRO_RENDEZVOUS", raising=False)
    assert env_hosts() is None
    monkeypatch.setenv("REPRO_HOSTS", "10.0.0.1:7000, 10.0.0.2:7000")
    assert env_hosts() == ["10.0.0.1:7000", "10.0.0.2:7000"]
    monkeypatch.delenv("REPRO_HOSTS")
    rv = tmp_path / "roster"
    rv.write_text("# fleet\nhostA:9001\n\nhostB:9002\n")
    monkeypatch.setenv("REPRO_RENDEZVOUS", str(rv))
    assert env_hosts() == ["hostA:9001", "hostB:9002"]


# -- tcp-only behavior --------------------------------------------------------

needs_tcp = pytest.mark.skipif(not HAVE_LOOPBACK,
                               reason="loopback sockets unavailable")


@needs_tcp
def test_tcp_payloads_never_ride_pickle():
    """Framing contract: payload buffers cross as raw blob bytes after the
    pickled skeleton, so the wire cost of a put is its size plus a small
    constant -- never a pickle blow-up."""
    import pickle

    from repro.core.transport.tcp import _restore, _strip

    data = np.arange(4096, dtype=np.uint8)
    msg = ("put", 7, 128, data)
    blobs = []
    skel = _strip(msg, blobs)
    assert len(blobs) == 1 and blobs[0].nbytes == 4096
    assert len(pickle.dumps(skel)) < 256  # the array left the skeleton
    blob = b"".join(bytes(memoryview(b).cast("B")) for b in blobs)
    back = _restore(skel, bytearray(blob), [0])
    assert back[0] == "put" and back[1] == 7 and back[2] == 128
    np.testing.assert_array_equal(back[3], data)
    # dtype/shape survive; nested containers and small scalars pass through
    arr = np.linspace(0.0, 1.0, 64).reshape(8, 8)
    msg2 = {"ops": [("acc", 0, arr, "sum")], "n": 3, "tag": b"id"}
    blobs2 = []
    skel2 = _strip(msg2, blobs2)
    blob2 = b"".join(bytes(memoryview(b).cast("B")) for b in blobs2)
    back2 = _restore(skel2, bytearray(blob2), [0])
    got = back2["ops"][0][2]
    assert got.dtype == np.float64 and got.shape == (8, 8)
    np.testing.assert_array_equal(got, arr)
    assert back2["n"] == 3 and back2["tag"] == b"id"


@needs_tcp
def test_tcp_handshake_rejects_wrong_token():
    """A misconfigured host (wrong fleet secret) must fail loudly at dial
    time, not corrupt another fleet's windows."""
    from repro.core.transport.tcp import TcpTransport, _TcpChannel

    t = TcpTransport(2)
    try:
        rogue = _TcpChannel(1, lambda: ("127.0.0.1", t._ports[1]),
                            b"wrong-token")
        with pytest.raises(TransportError, match="unreachable"):
            rogue.call(("ping",), timeout=5.0)
        rogue.close()
        assert t.probe(1)  # the rejected dial did not wedge the worker
    finally:
        t.shutdown()


@needs_tcp
def test_tcp_worker_kill_failover_and_cross_backend_recovery(tmp_path):
    """The byte-identical-layout claim, end to end: SIGKILL one tcp rank
    mid-run (probe reports it dead, replicated reads fail over, operations
    against it fail loudly), then a fresh *mp* world over the same files
    restores the job byte-exact -- crash under tcp, recover under mp."""
    rng = np.random.default_rng(11)
    words = "one two three four five six seven".split()
    tasks = [" ".join(rng.choice(words, 50)) for _ in range(8)]
    expect = {}
    for t in tasks:
        for k, v in wordcount_map(t).items():
            expect[k] = expect.get(k, 0) + v

    comm = Communicator(4, transport="tcp")
    mr = MapReduce1S(comm, 1 << 8, info=storage_info(tmp_path, "mr.bin"))
    my0 = mr._tasks_of(0, len(tasks))
    for pos in range(2):
        for k, v in wordcount_map(tasks[my0[pos]]).items():
            mr.table.insert(k, v, op="sum")
        mr._commit_task(0, pos)
    mr._drain_ckpt()
    done = mr.completed_tasks()
    assert done == 2

    victim = comm.transport._procs[1]
    victim.kill()
    victim.join(timeout=10)
    assert comm.transport.probe(1) is False
    with pytest.raises(TransportError, match="unreachable"):
        mr.table.win.get(1, 0, 8)
    with pytest.raises(TransportError):
        comm.close()
    for p in comm.transport._procs:
        assert not p.is_alive()

    # recovery on a DIFFERENT backend: the mp world reads the tcp world's
    # files (same <file>.<rank> naming) and resumes, replaying the
    # unfinished tasks
    comm2 = Communicator(4, transport="mp")
    mr2 = MapReduce1S(comm2, 1 << 8, info=storage_info(tmp_path, "mr.bin"),
                      resume=True)
    assert mr2.completed_tasks() == done
    mr2.run(tasks)
    assert mr2.result() == expect
    mr2.free()
    comm2.close()


@needs_tcp
def test_tcp_replicated_failover_and_respawn_rebuild(tmp_path):
    """Kill one tcp rank holding a replicated storage window: synced bytes
    stay readable via the replica, respawn_rank brings a fresh worker up
    on a new port, and rebuild_rank restores the partition bit-exact."""
    comm = Communicator(3, transport="tcp")
    try:
        win = Window.allocate(comm, 16384, info={
            "alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / "rep.bin"),
            "storage_alloc_replication": "2"})
        synced = np.random.default_rng(5).integers(
            0, 255, 16384).astype(np.uint8)
        win.put(synced, 1, 0)
        win.sync(1)

        comm.transport._procs[1].kill()
        comm.transport._procs[1].join(timeout=10)
        assert comm.probe(1) is False

        # zero lost synced bytes: the window read fails over to a replica
        got = win.get(1, 0, 16384)
        np.testing.assert_array_equal(np.asarray(got), synced)

        comm.rebuild_rank(1)
        assert comm.probe(1) is True
        prim = np.asarray(comm.transport.get(win.segments[1], 0, 16384))
        np.testing.assert_array_equal(prim, synced)
        win.free()
    finally:
        comm.close()


@needs_tcp
def test_tcp_memory_windows_volatile_storage_durable(tmp_path):
    """tcp has no shared memory: a memory window is served from the owning
    rank's address space (no local view), while a storage window's bytes
    land on disk under the same naming as every other backend."""
    from repro.core import WindowError

    comm = Communicator(2, transport="tcp")
    try:
        with Window.allocate(comm, 256) as win:
            win.put(np.full(8, 5, np.uint8), 1, 0)
            assert (win.get(1, 0, 8) == 5).all()
            with pytest.raises(WindowError):
                win.shared_view()  # nothing to map across a socket
        with Window.allocate(comm, 4096,
                             info=storage_info(tmp_path, "t.bin")) as win:
            win.put(np.full(16, 9, np.uint8), 1, 32)
            win.sync(1)
        raw = np.fromfile(str(tmp_path / "t.bin.1"), dtype=np.uint8)
        assert (raw[32:48] == 9).all()
    finally:
        comm.close()
