"""Backings: dirty tracking, striping, page cache vs mmap equivalence."""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.storage import (CachedBacking, DirtyTracker, MmapBacking,
                                StripedFile, make_backing)


# -- DirtyTracker ------------------------------------------------------------

def test_tracker_basic():
    t = DirtyTracker(10000, page_size=1024)
    assert t.num_blocks == 10 and t.dirty_count == 0
    t.mark(1500, 10)
    assert t.dirty_count == 1 and t.is_dirty(1)
    t.mark(1020, 3000)  # spans blocks 0..3
    assert t.dirty_count == 4
    mask = t.snapshot_and_clear()
    assert mask.sum() == 4 and t.dirty_count == 0


@given(ops=st.lists(st.tuples(st.integers(0, 9999), st.integers(1, 5000)),
                    max_size=30))
def test_tracker_matches_model(ops):
    t = DirtyTracker(10000, page_size=512)
    model = np.zeros(10000, bool)
    for off, n in ops:
        n = min(n, 10000 - off)
        if n <= 0:
            continue
        t.mark(off, n)
        model[off:off + n] = True
    blocks = model.reshape(-1, 512) if model.size % 512 == 0 else None
    expect = np.zeros(t.num_blocks, bool)
    for b in range(t.num_blocks):
        expect[b] = model[b * 512:(b + 1) * 512].any()
    got = t.snapshot_and_clear()
    assert (got == expect).all()


def test_dirty_runs():
    t = DirtyTracker(8192, page_size=1024)
    t.mark(0, 1024)
    t.mark(3 * 1024, 2048)
    runs = t.dirty_runs()
    assert runs == [(0, 1), (3, 5)]


# -- StripedFile ----------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(factor=st.integers(1, 4), unit=st.sampled_from([64, 256, 1000]),
       writes=st.lists(st.tuples(st.integers(0, 4000), st.binary(min_size=1,
                                                                 max_size=600)),
                       max_size=10))
def test_striped_file_matches_flat_model(tmp_path_factory, factor, unit, writes):
    d = tmp_path_factory.mktemp("stripe")
    size = 5000
    sf = StripedFile(str(d / "f.bin"), size, striping_factor=factor,
                     striping_unit=unit)
    model = bytearray(size)
    try:
        for off, data in writes:
            data = data[: size - off]
            if not data:
                continue
            sf.pwrite(off, data)
            model[off:off + len(data)] = data
        assert sf.pread(0, size) == bytes(model)
    finally:
        sf.close(unlink=True)


def test_striping_actually_splits(tmp_path):
    sf = StripedFile(str(tmp_path / "s.bin"), 4096, striping_factor=4,
                     striping_unit=512)
    sf.pwrite(0, b"\xff" * 4096)
    sf.close()
    for i in range(4):
        assert os.path.getsize(tmp_path / f"s.bin.stripe{i}") == 1024


# -- backings ---------------------------------------------------------------------

@pytest.mark.parametrize("mechanism", ["mmap", "cached"])
def test_backing_roundtrip_and_sync(tmp_file, mechanism):
    b = make_backing(tmp_file, 8192, mechanism=mechanism)
    data = np.arange(256, dtype=np.uint8)
    b.write(100, data)
    assert (b.read(100, 256) == data).all()
    flushed = b.sync()
    assert flushed > 0
    assert b.sync() == 0  # selective: nothing dirty anymore
    b.close()


@settings(deadline=None, max_examples=20)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["r", "w"]), st.integers(0, 4000),
              st.integers(1, 900)), min_size=1, max_size=25))
def test_cached_equals_mmap(tmp_path_factory, ops):
    """The user-level page cache is observationally identical to mmap."""
    d = tmp_path_factory.mktemp("eq")
    size = 4096 + 1000
    a = make_backing(str(d / "a.bin"), size, mechanism="mmap")
    b = make_backing(str(d / "b.bin"), size, mechanism="cached",
                     cache_bytes=3 * 4096)  # small cache: forces eviction
    rng = np.random.default_rng(1)
    try:
        for kind, off, n in ops:
            n = min(n, size - off)
            if n <= 0:
                continue
            if kind == "w":
                data = rng.integers(0, 256, n).astype(np.uint8)
                a.write(off, data)
                b.write(off, data)
            else:
                assert (a.read(off, n) == b.read(off, n)).all()
        a.sync(); b.sync()
        raw_a = a.read(0, size)
        raw_b = b.read(0, size)
        assert (raw_a == raw_b).all()
    finally:
        a.close(); b.close()


def test_cached_eviction_persists(tmp_file):
    """Evicted dirty blocks must be written back, not lost."""
    b = CachedBacking(tmp_file, 64 * 4096, cache_bytes=2 * 4096)
    for blk in range(64):
        b.write(blk * 4096, np.full(4096, blk % 251, np.uint8))
    for blk in range(64):
        assert (b.read(blk * 4096, 4096) == blk % 251).all()
    assert b.evictions > 0
    b.close()


def test_compare_on_write_keeps_clean(tmp_file):
    b = CachedBacking(tmp_file, 4 * 4096, compare_on_write=True)
    data = np.full(4096, 7, np.uint8)
    b.write(0, data)
    assert b.sync() == 4096
    b.write(0, data)            # identical content
    assert b.sync() == 0        # stays clean
    data2 = data.copy(); data2[100] = 8
    b.write(0, data2)
    assert b.sync() == 4096     # real change flushes
    b.close()


def test_dirty_ratio_forces_flush(tmp_file):
    b = CachedBacking(tmp_file, 10 * 4096, dirty_ratio=0.3)
    for blk in range(10):
        b.write(blk * 4096, np.full(4096, 1, np.uint8))
    # vm.dirty_ratio analogue: flushes happened inside write()
    assert b.bytes_flushed > 0
    b.close()


def test_background_flusher(tmp_file):
    import time
    b = CachedBacking(tmp_file, 4 * 4096, writeback_interval=0.05)
    b.write(0, np.full(4096, 3, np.uint8))
    time.sleep(0.4)
    assert b.tracker.dirty_count == 0  # flusher cleaned it
    assert b.sync() == 0
    b.close()


def test_unlink_and_discard(tmp_path):
    p = str(tmp_path / "u.bin")
    b = make_backing(p, 4096, mechanism="cached")
    b.write(0, np.full(10, 1, np.uint8))
    b.close(unlink=True)
    assert not os.path.exists(p)
