"""Paper applications: DHT (§3.3/§3.4) and MapReduce-1S (§3.5.2)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Communicator, DistributedHashTable, MapReduce1S
from repro.core.mapreduce import stable_word_key, wordcount_map


def storage_info(tmp_path, name):
    return {"alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / name)}


@settings(deadline=None, max_examples=15)
@given(keys=st.lists(st.integers(1, 500), min_size=1, max_size=200))
def test_dht_matches_dict_sum(tmp_path_factory, keys):
    d = tmp_path_factory.mktemp("dht")
    dht = DistributedHashTable(Communicator(4), 32,
                               info=storage_info(d, "t.bin"))
    ref = {}
    try:
        for k in keys:
            dht.insert(k, 1, op="sum")
            ref[k] = ref.get(k, 0) + 1
        assert dict(dht.items()) == ref
        for k in list(ref)[:20]:
            assert dht.lookup(k) == ref[k]
        assert dht.lookup(10**9) is None
    finally:
        dht.free()


def test_dht_replace_semantics(tmp_path):
    dht = DistributedHashTable(Communicator(2), 16)
    dht.insert(42, 1)
    dht.insert(42, 9)  # replace
    assert dht.lookup(42) == 9
    dht.free()


def test_dht_memory_vs_storage_equivalent(tmp_path):
    """Paper's headline property: same data structure, hints decide tier."""
    rng = np.random.default_rng(2)
    keys = rng.integers(1, 1000, 300)
    d_mem = DistributedHashTable(Communicator(4), 64)
    d_sto = DistributedHashTable(Communicator(4), 64,
                                 info=storage_info(tmp_path, "s.bin"))
    for k in keys:
        d_mem.insert(int(k), 1, op="sum")
        d_sto.insert(int(k), 1, op="sum")
    assert dict(d_mem.items()) == dict(d_sto.items())
    assert d_sto.sync() >= 0
    d_mem.free(); d_sto.free()


def test_dht_out_of_core_combined(tmp_path):
    """§3.4: combined allocation with a memory budget below the table size."""
    info = storage_info(tmp_path, "oo.bin")
    info["storage_alloc_factor"] = "auto"
    dht = DistributedHashTable(Communicator(2), 256, heap_factor=4,
                               info=info, memory_budget=4096)
    seg = dht.win.segments[0]
    assert seg.sto_bytes > 0  # actually spilled
    ref = {}
    rng = np.random.default_rng(3)
    for k in rng.integers(1, 2000, 500):
        dht.insert(int(k), 1, op="sum")
        ref[int(k)] = ref.get(int(k), 0) + 1
    assert dict(dht.items()) == ref
    dht.free()


def test_wordcount_map():
    c = wordcount_map("the cat and the hat")
    assert c[stable_word_key("the")] == 2
    assert c[stable_word_key("cat")] == 1


def test_mapreduce_equals_reference(tmp_path):
    tasks = [f"alpha beta gamma {'delta ' * i}" for i in range(9)]
    mr = MapReduce1S(Communicator(3), 128, info=storage_info(tmp_path, "mr.bin"))
    mr.run(tasks)
    got = mr.result()
    ref = {}
    for t in tasks:
        for k, v in wordcount_map(t).items():
            ref[k] = ref.get(k, 0) + v
    assert got == ref
    assert mr.ckpt_count == 9  # one transparent checkpoint per map task
    mr.free()


def test_mapreduce_restart_resumes(tmp_path):
    """Kill between tasks -> resume from the progress window, same result."""
    tasks = [f"w{i} common common" for i in range(12)]
    comm = Communicator(2)
    mr = MapReduce1S(comm, 128, info=storage_info(tmp_path, "r.bin"))
    # run rank 0's first 3 tasks only, then "crash"
    my0 = mr._tasks_of(0, len(tasks))
    for pos in range(3):
        part = wordcount_map(tasks[my0[pos]])
        for k, v in part.items():
            mr.table.insert(k, v, op="sum")
        mr._commit_task(0, pos)
    done_before = mr.completed_tasks()
    assert done_before == 3
    mr.run(tasks)  # resumes: rank0 from task 3, rank1 from 0
    got = mr.result()
    ref = {}
    for t in tasks:
        for k, v in wordcount_map(t).items():
            ref[k] = ref.get(k, 0) + v
    assert got == ref
    mr.free()


def test_mapreduce_checkpoint_is_incremental(tmp_path):
    """Selective sync: per-task checkpoint bytes << full table size."""
    tasks = ["tiny task"] * 6
    mr = MapReduce1S(Communicator(2), 1 << 12,
                     info=storage_info(tmp_path, "i.bin"))
    mr.run(tasks)
    table_bytes = mr.table.segment_bytes * 2
    # total ckpt traffic should be far below 6 full-table writes
    assert mr.ckpt_bytes < 2 * table_bytes
    mr.free()
