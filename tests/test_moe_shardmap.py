"""Explicit-EP shard_map MoE == dense (GSPMD) MoE, forward and gradients.

Runs in a subprocess with 8 forced host devices on a (2, 4) mesh.
"""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import param_specs, init_params
from repro.models.moe import moe_mlp_dense, _moe_mlp_shard_map

cfg = get_config("deepseek-v2-236b", smoke=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = init_params(param_specs(cfg), jax.random.PRNGKey(0))
mp = {k.removeprefix("g1/p0/"): v[0] for k, v in params.items()
      if k.startswith("g1/p0/")}
mp = {k: v.astype(jnp.bfloat16) if v.ndim >= 2 else v for k, v in mp.items()}
x = (jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                       jnp.bfloat16) * 0.3)
y_dense, _ = jax.jit(lambda xx: moe_mlp_dense(cfg, mp, xx, capacity=64))(x)
with mesh:
    sharding = NamedSharding(mesh, P("data", None, None))
    f = jax.jit(lambda xx: _moe_mlp_shard_map(cfg, mp, xx, mesh, capacity=64),
                in_shardings=sharding)
    y_sm, _ = f(jax.device_put(x, sharding))
a = np.asarray(y_dense, np.float32); b = np.asarray(y_sm, np.float32)
err = np.abs(a - b).max() / max(1e-6, np.abs(a).max())
assert err < 0.05, err

def loss(xx):
    y, aux = _moe_mlp_shard_map(cfg, mp, xx, mesh, capacity=64)
    return (y.astype(jnp.float32) ** 2).sum() + aux
with mesh:
    g = jax.jit(jax.grad(loss))(x)
assert np.isfinite(np.asarray(g, np.float32)).all()
print("OK", err)
"""


def test_moe_shardmap_equals_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + "/src"
    out = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                         text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
