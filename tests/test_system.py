"""End-to-end behaviour: the paper's full story on one tiny model.

Train with windows-backed state -> crash mid-run -> restart from the
selective-sync checkpoint -> final params identical to an uninterrupted
run; plus the out-of-core + parallel-I/O paths exercised together.
"""

import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Communicator, DistributedHashTable, MapReduce1S
from repro.data import SyntheticLM, WindowBackedDataset
from repro.train import AdamWConfig, Trainer, TrainConfig


def test_full_story(tmp_path):
    cfg = get_config("internlm2-1.8b", smoke=True)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50)
    comm = Communicator(1)

    # 1. the *input data* lives in a storage window (parallel I/O as reads)
    ds_file = str(tmp_path / "corpus.bin")
    wds = WindowBackedDataset(comm, ds_file, tokens_per_rank=1 << 14)
    rng = np.random.default_rng(0)
    wds.write_corpus(0, rng.integers(0, cfg.vocab, 1 << 14).astype(np.int32))

    class WinIter:
        step = 0
        def __next__(self):
            b = wds.batch_at(0, WinIter.step, batch=2, seq=16)
            WinIter.step += 1
            return {k: v[None] for k, v in b.items()}  # microbatch axis

    # 2. train with transparent checkpointing, crash at step 6
    ck = str(tmp_path / "ck")
    tc = TrainConfig(steps=12, microbatches=1, log_every=0, ckpt_dir=ck,
                     ckpt_every=3, ckpt_async=False)
    tr1 = Trainer(cfg, opt, tc)
    tr1.run(WinIter(), stop_after=6)

    # 3. "crash" -> fresh trainer restores from the last good manifest
    tr2 = Trainer(cfg, opt, tc)
    it = WinIter(); WinIter.step = 6
    p_resumed, _ = tr2.run(it)

    # 4. uninterrupted reference run over the identical data stream
    WinIter.step = 0
    tr3 = Trainer(cfg, opt, TrainConfig(steps=12, microbatches=1, log_every=0))
    p_ref, _ = tr3.run(WinIter())

    for k in p_ref:
        np.testing.assert_allclose(np.asarray(p_ref[k], np.float32),
                                   np.asarray(p_resumed[k], np.float32),
                                   atol=1e-5, rtol=1e-4)
    tr1.close(); tr2.close(); tr3.close()
    wds.free()


def test_paper_apps_share_window_files(tmp_path):
    """DHT state written through windows is plain bytes on disk -- the same
    files a restarted process (or another tool) can read back."""
    comm = Communicator(2)
    path = tmp_path / "dht.bin"
    dht = DistributedHashTable(comm, 32, info={
        "alloc_type": "storage", "storage_alloc_filename": str(path)})
    for k in range(1, 40):
        dht.insert(k, k * k)
    dht.sync()
    dht.free()
    assert os.path.exists(str(path) + ".0") and os.path.exists(str(path) + ".1")
    total = sum(os.path.getsize(f"{path}.{r}") for r in range(2))
    assert total == dht.segment_bytes * 2
