"""Resilience subsystem conformance: placement, mirroring, failure
detection, failover reads/writes, and live rebuild.

The in-process half simulates rank death with ``comm.mark_dead`` (the
routing/mirroring logic is transport-independent); the mp half SIGKILLs
real workers -- the acceptance path: probe/HeartbeatMonitor report the
death, DHT reads and writes keep succeeding via failover with zero lost
synced data, and a respawned worker rebuilds its partition bit-exact.
"""

import numpy as np
import pytest

from repro.core import (Communicator, DistributedHashTable, FailureDetector,
                        ReplicaPlacement, Window, WindowError)
from repro.core.hints import HintError, WindowHints
from repro.runtime.fault import HeartbeatMonitor

try:
    import multiprocessing.shared_memory  # noqa: F401
    HAVE_SHM = True
except ImportError:  # pragma: no cover - exotic platforms
    HAVE_SHM = False

needs_shm = pytest.mark.skipif(
    not HAVE_SHM, reason="multiprocessing.shared_memory unavailable")


def rep_info(tmp_path, k=2, name="w.bin"):
    return {"alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / name),
            "storage_alloc_replication": str(k)}


# -- placement ----------------------------------------------------------------

def test_placement_chain_order():
    p = ReplicaPlacement(4, 3)
    assert p.holders(0) == (0, 1, 2)
    assert p.holders(3) == (3, 0, 1)
    assert p.replicas(2) == (3, 0)
    # inverse rotation: every rank hosts exactly k-1 copies
    for h in range(4):
        assert len(p.held_by(h)) == 2
        for q in p.held_by(h):
            assert h in p.holders(q)
    assert p.copy_index(3, 0) == 1 and p.copy_index(3, 3) == 0
    with pytest.raises(ValueError, match="holds no copy"):
        p.copy_index(0, 3)


def test_placement_validation():
    with pytest.raises(ValueError):
        ReplicaPlacement(2, 3)  # k > nranks
    with pytest.raises(ValueError):
        ReplicaPlacement(4, 0)
    with pytest.raises(ValueError):
        ReplicaPlacement(4, 2).holders(4)


# -- hint parsing -------------------------------------------------------------

def test_replication_hint_parsing():
    h = WindowHints.from_info({"alloc_type": "storage",
                               "storage_alloc_filename": "/tmp/x",
                               "storage_alloc_replication": "3"})
    assert h.replication == 3
    assert WindowHints.from_info(None).replication == 1
    for bad in ("0", "-1", "two"):
        with pytest.raises(HintError):
            WindowHints.from_info({"alloc_type": "storage",
                                   "storage_alloc_filename": "/tmp/x",
                                   "storage_alloc_replication": bad})


def test_replication_advisory_clamps_and_ignores(tmp_path):
    # memory windows ignore the hint (replicas must be durable)
    comm = Communicator(4)
    with Window.allocate(comm, 256,
                         info={"storage_alloc_replication": "2"}) as win:
        assert win.replication == 1 and not win.replicated
    # k is clamped to the communicator size (advisory, like every hint)
    solo = Communicator(1)
    with Window.allocate(solo, 256, info=rep_info(tmp_path, k=3)) as win:
        assert win.replication == 1
    solo.close()
    comm.close()


# -- mirroring ----------------------------------------------------------------

def test_sync_mirrors_written_spans_to_replica_files(tmp_path):
    comm = Communicator(4)
    win = Window.allocate(comm, 8192, info=rep_info(tmp_path, k=2))
    data = np.arange(512, dtype=np.int64)
    win.put(data.view(np.uint8), 3, 256)
    # before the sync nothing is mirrored (and nothing persisted)
    assert np.fromfile(str(tmp_path / "w.bin.rep1.3"), np.uint8).sum() == 0
    flushed = win.sync(3)
    assert flushed > 0
    raw = np.fromfile(str(tmp_path / "w.bin.rep1.3"), dtype=np.uint8)
    assert (raw[256:256 + data.nbytes].view(np.int64) == data).all()
    # second sync: clean window, nothing to re-mirror
    assert win.sync(3) == 0
    win.free()
    comm.close()


def test_flush_async_epoch_means_k_durable_copies(tmp_path):
    comm = Communicator(2)
    win = Window.allocate(comm, 4096, info=rep_info(tmp_path, k=2))
    win.rput(np.full(4096, 7, np.uint8), 0, 0).wait()
    req = win.flush_async(0)
    assert req.wait() > 0
    win.flush(0)  # epoch boundary: k durable copies
    rep = np.fromfile(str(tmp_path / "w.bin.rep1.0"), dtype=np.uint8)
    assert (rep == 7).all()
    win.free()
    comm.close()


def test_mirror_failure_remarks_spans(tmp_path):
    """A mirror with no live replica target keeps the spans pending
    (replay, never skip): they mirror on the next sync after rebuild."""
    comm = Communicator(2)
    win = Window.allocate(comm, 4096, info=rep_info(tmp_path, k=2))
    comm.mark_dead(1)  # rank 0's only replica holder is down
    win.put(np.full(64, 5, np.uint8), 0, 0)
    win.sync(0)  # primary durable; mirror degraded -> spans stay pending
    assert win._mirror_pending[0].dirty_count > 0
    comm.mark_alive(1)
    win.sync(0)  # no new dirty data, but the pending mirror replays
    assert win._mirror_pending[0].dirty_count == 0
    rep = np.fromfile(str(tmp_path / "w.bin.rep1.0"), dtype=np.uint8)
    assert (rep[:64] == 5).all()
    win.free()
    comm.close()


# -- failover (simulated, in-process) -----------------------------------------

def test_failover_reads_writes_and_rebuild(tmp_path):
    comm = Communicator(4)
    win = Window.allocate(comm, 8192, info=rep_info(tmp_path, k=2))
    data = np.arange(1024, dtype=np.int64)
    win.put(data.view(np.uint8), 1, 0)
    win.sync(1)
    comm.mark_dead(1)
    # reads serve every synced byte from the replica
    assert (win.get(1, 0, 1024, np.int64) == data).all()
    # writes land on the acting replica, atomics included
    win.put(np.full(8, 9, np.uint8), 1, 8192 - 8)
    win.accumulate(np.asarray([100], np.int64), 1, 0, op="sum")
    assert win.get(1, 0, 1, np.int64)[0] == data[0] + 100
    assert win.compare_and_swap(-5, data[1] + 0, 1, 8, np.int64) == data[1]
    win.sync(1)
    # rebuild reconciles the (stale) primary from the acting replica
    copied = win.rebuild_rank(1)
    assert copied > 0
    assert 1 not in comm.dead_ranks
    assert win.get(1, 0, 1, np.int64)[0] == data[0] + 100
    assert win.get(1, 8, 1, np.int64)[0] == -5
    assert (win.get(1, 8192 - 8, 8) == 9).all()
    win.free()
    comm.close()


def test_sync_from_device_failover_inproc(tmp_path):
    """The device-mask path routes through the acting holder like put():
    with the primary (simulated) dead, the changed spans and the masked
    flush land on the replica -- no TransportError, no full-window I/O."""
    pytest.importorskip("jax.numpy")
    PAGE = 4096
    comm = Communicator(2)
    win = Window.allocate(comm, 16 * PAGE, info=rep_info(tmp_path, k=2))
    elems = 16 * PAGE // 4
    state = np.arange(elems, dtype=np.float32)
    win.put(state, 0, 0)
    win.sync(0)  # k durable copies of the baseline
    comm.mark_dead(0)
    cur = state.copy()
    cur[(PAGE // 4) * 4 + 2] += 1.0   # page 4
    flushed = win.sync_from_device(0, cur, state, blocking=True)
    assert flushed == PAGE
    # the acting replica holds (and persisted) the change...
    assert (win.get(0, 0, elems, np.float32) == cur).all()
    rep = np.fromfile(str(tmp_path / "w.bin.rep1.0"), np.float32)
    assert (rep == cur).all()
    # ...and the primary's file stayed at the old epoch (it is dead)
    prim = np.fromfile(str(tmp_path / "w.bin.0"), np.float32)
    assert (prim == state).all()
    # the nonblocking variant takes the same route
    cur2 = cur.copy()
    cur2[(PAGE // 4) * 9] += 1.0      # page 9
    assert win.sync_from_device(0, cur2, cur).wait(timeout=30.0) == PAGE
    rep = np.fromfile(str(tmp_path / "w.bin.rep1.0"), np.float32)
    assert (rep == cur2).all()
    comm.mark_alive(0)
    win.rebuild_rank(0)  # reconcile the stale primary before teardown
    win.free()
    comm.close()


@needs_shm
def test_mp_sync_from_device_failover_survives_sigkill(tmp_path):
    """ISSUE regression: SIGKILL the primary's worker, then run
    sync_from_device against it -- the TransportError surfaces *inside*
    the op, fails over to the replica holder, and the masked span write
    completes there (replay of the whole span set, never a partial
    epoch)."""
    pytest.importorskip("jax.numpy")
    PAGE = 4096
    comm = Communicator(2, transport="mp")
    try:
        win = Window.allocate(comm, 16 * PAGE, info=rep_info(tmp_path, k=2))
        elems = 16 * PAGE // 4
        state = np.random.default_rng(7).standard_normal(elems).astype(
            np.float32)
        win.put(state, 0, 0)
        win.sync(0)  # baseline durable on both holders

        comm.transport._procs[0].kill()
        comm.transport._procs[0].join(timeout=10)
        assert 0 not in comm.dead_ranks  # death not yet observed

        cur = state.copy()
        cur[(PAGE // 4) * 2 + 1] += 1.0   # page 2
        cur[(PAGE // 4) * 9 + 5] += 1.0   # page 9
        flushed = win.sync_from_device(0, cur, state, blocking=True)
        assert flushed == 2 * PAGE
        assert 0 in comm.dead_ranks  # the op discovered the death itself
        assert (win.get(0, 0, elems, np.float32) == cur).all()
        rep = np.fromfile(str(tmp_path / "w.bin.rep1.0"), np.float32)
        assert (rep == cur).all()
        win.free()  # survivable teardown: every partition has a live holder
    finally:
        comm.close()


def test_failover_exhausted_raises(tmp_path):
    comm = Communicator(4)
    win = Window.allocate(comm, 1024, info=rep_info(tmp_path, k=2))
    comm.mark_dead(0)
    comm.mark_dead(1)  # both holders of partition 0 are gone
    with pytest.raises(WindowError, match="no live holder"):
        win.get(0, 0, 8)
    comm.mark_alive(0)
    comm.mark_alive(1)
    win.free()
    comm.close()


def test_unreplicated_windows_unchanged(tmp_path):
    """No hint, no behavior change: a marked-dead rank on an unreplicated
    inproc window still serves (inproc segments cannot actually die)."""
    comm = Communicator(2)
    win = Window.allocate(comm, 1024, info={
        "alloc_type": "storage",
        "storage_alloc_filename": str(tmp_path / "plain.bin")})
    assert not win.replicated and win.replica_segs == {}
    comm.mark_dead(1)
    win.put(np.full(8, 3, np.uint8), 1, 0)  # routes to the primary, as ever
    assert (win.get(1, 0, 8) == 3).all()
    win.free()
    comm.close()


def test_dht_failover_inproc(tmp_path):
    comm = Communicator(4)
    dht = DistributedHashTable(comm, 64, info={
        "alloc_type": "storage",
        "storage_alloc_filename": str(tmp_path / "dht.bin")}, replication=2)
    expect = {int(k): i for i, k in enumerate(
        np.random.default_rng(5).integers(1, 1 << 40, 150))}
    for k, v in expect.items():
        dht.insert(k, v, op="replace")
    dht.sync()
    comm.mark_dead(2)
    assert all(dht.lookup(k) == v for k, v in expect.items())
    for k in list(expect)[:20]:  # writes through failover
        dht.insert(k, expect[k] + 1, op="replace")
        expect[k] += 1
    assert all(dht.lookup(k) == v for k, v in expect.items())
    comm.rebuild_rank(2)
    assert all(dht.lookup(k) == v for k, v in expect.items())
    assert sorted(dht.items()) == sorted(expect.items())
    dht.free()
    comm.close()


def test_ckpt_manager_replicated_restore_survives_rank_death(tmp_path):
    from repro.ckpt import CheckpointManager
    comm = Communicator(2)
    specs = {"w": ((2048,), np.float32)}
    cm = CheckpointManager(str(tmp_path), comm, specs, replication=2)
    w = np.random.default_rng(0).standard_normal(2048).astype(np.float32)
    cm.save(1, {"w": w})
    # the saving rank dies: the manifest's data is still restorable,
    # served transparently from the replica
    comm.mark_dead(0)
    r = cm.restore()
    assert r is not None and r.step == 1 and (r.tree["w"] == w).all()
    comm.mark_alive(0)
    cm.close()
    comm.close()


def test_detector_feeds_monitor_inproc():
    comm = Communicator(3)
    hb = HeartbeatMonitor(3)
    fd = FailureDetector(comm, hb)
    assert fd.poll(0) == []
    assert hb.dead() == []  # every rank beaten
    comm.mark_dead(2)
    assert fd.poll(1) == [2]
    assert hb.dead() == [2]
    comm.close()


# -- multiprocess: the acceptance path ----------------------------------------

@needs_shm
def test_mp_probe_detects_sigkill():
    comm = Communicator(2, transport="mp")
    try:
        assert comm.probe(1) is True
        comm.transport._procs[1].kill()
        comm.transport._procs[1].join(timeout=10)
        assert comm.probe(1) is False
        assert 1 in comm.dead_ranks  # probe marked it for failover routing
    finally:
        comm.close()


@needs_shm
def test_mp_sigkill_failover_and_bitexact_rebuild(tmp_path):
    """ISSUE acceptance: REPRO_TRANSPORT=mp + storage_alloc_replication=2,
    SIGKILL one worker mid-workload -> DHT reads/writes keep succeeding via
    failover with zero lost synced data, probe/HeartbeatMonitor report the
    rank dead, and a respawned worker rebuilds bit-exact from replicas."""
    comm = Communicator(4, transport="mp")
    try:
        dht = DistributedHashTable(comm, 128, info={
            "alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / "dht.bin")},
            replication=2)
        expect = {int(k): i for i, k in enumerate(
            np.random.default_rng(9).integers(1, 1 << 40, 120))}
        for k, v in expect.items():
            dht.insert(k, v, op="replace")
        dht.sync()  # durability point: 2 copies of every partition

        victim = 1
        comm.transport._procs[victim].kill()
        comm.transport._procs[victim].join(timeout=10)

        # detection: probe and monitor agree, without touching a data path
        hb = HeartbeatMonitor(4)
        assert FailureDetector(comm, hb).poll(0) == [victim]
        assert hb.dead() == [victim]

        # service: zero lost synced data, reads AND writes
        assert all(dht.lookup(k) == v for k, v in expect.items())
        extra = {int(k): -i for i, k in enumerate(
            np.random.default_rng(10).integers(1 << 40, 1 << 41, 40))}
        for k, v in extra.items():
            dht.insert(k, v, op="replace")
        expect.update(extra)
        assert all(dht.lookup(k) == v for k, v in expect.items())
        dht.sync()

        # respawn + rebuild: bit-exact partition, rank back in service
        comm.rebuild_rank(victim)
        assert comm.probe(victim) is True
        win = dht.win
        prim = np.asarray(comm.transport.get(
            win.segments[victim], 0, win.segments[victim].size))
        rep = np.asarray(comm.transport.get(
            win.replica_segs[(victim, 1)], 0, win.segments[victim].size))
        assert (prim == rep).all()
        assert all(dht.lookup(k) == v for k, v in expect.items())
        dht.free()
    finally:
        comm.close()


@needs_shm
def test_mp_window_failover_zero_lost_synced_bytes(tmp_path):
    comm = Communicator(3, transport="mp")
    try:
        win = Window.allocate(comm, 16384, info=rep_info(tmp_path, k=2))
        synced = np.random.default_rng(1).integers(
            0, 255, 16384).astype(np.uint8)
        win.put(synced, 2, 0)
        win.sync(2)
        win.put(np.full(64, 200, np.uint8), 2, 0)  # un-synced overwrite
        comm.transport._procs[2].kill()
        comm.transport._procs[2].join(timeout=10)
        # the un-synced page cache is lost (paper failure model); every
        # synced byte survives, served from the replica
        got = win.get(2, 0, 16384)
        assert (got == synced).all()
        win.free()
    finally:
        comm.close()


def test_replica_reads_spread_across_live_holders(tmp_path):
    """Reads of a synced replicated partition rotate across its live
    holders (load spreading) instead of pinning the acting holder; an
    un-mirrored write pins reads back to the acting holder until the next
    sync (read-your-writes), and a single live holder serves alone."""
    comm = Communicator(2)
    win = Window.allocate(comm, 8192, info=rep_info(tmp_path, k=2))
    try:
        win.put(np.full(64, 5, np.uint8), 0, 0)
        win.sync(0)  # mirrored: both holders now carry the bytes
        served = []
        orig = comm.transport.get

        def counting(seg, off, n):
            served.append(id(seg))
            return orig(seg, off, n)

        comm.transport.get = counting
        try:
            for _ in range(6):
                assert (win.get(0, 0, 64) == 5).all()
            assert len(set(served)) == 2  # both holders served traffic
            # an un-mirrored write makes reads sticky to the acting holder
            win.put(np.full(64, 6, np.uint8), 0, 0)
            served.clear()
            for _ in range(4):
                assert (win.get(0, 0, 64) == 6).all()
            assert len(set(served)) == 1
            win.sync(0)  # mirror the 6s, then kill the primary
            comm.mark_dead(0)
            served.clear()
            for _ in range(4):
                assert (win.get(0, 0, 64) == 6).all()
            assert len(set(served)) == 1  # only the replica is left
        finally:
            comm.transport.get = orig
        win.free()
    finally:
        comm.close()


@needs_shm
def test_mp_notified_completion_failover_replay(tmp_path):
    """A posted (notified) train whose holder is SIGKILLed before the
    completion read is replayed on the next live replica at the flush
    boundary -- replay-never-skip for the aggregation hot path."""
    comm = Communicator(4, transport="mp")
    try:
        win = Window.allocate(comm, 8192, info=rep_info(tmp_path, k=2))
        data = np.full(64, 42, np.uint8)
        req = win.rput(data, 0, 0)
        req.wait()  # train posted to rank 0 (optimistic local completion)
        comm.transport._procs[0].kill()
        comm.transport._procs[0].join(timeout=10)
        win.flush(0)  # completion read fails -> mark dead -> replay on 1
        assert 0 in comm.dead_ranks
        assert (win.get(0, 0, 64) == data).all()  # replica serves them
        win.free()
    finally:
        comm.close()
