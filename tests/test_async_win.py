"""Nonblocking one-sided layer: rput/rget/raccumulate Requests, the async
flush pipeline, epoch completion, and the paper's durability semantics."""

import os
import threading
import time

import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.core import Communicator, Request, Window
from repro.train.offload_opt import OutOfCoreAdamW
from repro.train.optimizer import AdamWConfig

PAGES = 16  # windows sized so small writes stay under vm.dirty_ratio


def storage_info(tmp_path, name="w.bin"):
    return {"alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / name)}


def backing_file(tmp_path, name, rank, nranks):
    base = str(tmp_path / name)
    return base if nranks == 1 else f"{base}.{rank}"


def test_rput_rget_waitall_end_to_end(tmp_path):
    """Acceptance: request-based RMA across all ranks of a storage window,
    completed with waitall, then persisted and verified on disk."""
    comm = Communicator(4)
    win = Window.allocate(comm, PAGES * 4096, info=storage_info(tmp_path))
    puts = [win.rput(np.full(256, r + 1, np.uint8), r, 128) for r in range(4)]
    assert Request.waitall(puts) == [None] * 4
    gets = [win.rget(r, 128, 256) for r in range(4)]
    vals = Request.waitall(gets)
    for r in range(4):
        assert (vals[r] == r + 1).all()
    assert win.sync() > 0
    for r in range(4):
        raw = np.fromfile(backing_file(tmp_path, "w.bin", r, 4), np.uint8)
        assert (raw[128:384] == r + 1).all()
    win.free()


def test_request_test_wait_semantics():
    comm = Communicator(1)
    win = Window.allocate(comm, 4096)
    req = win.rget(0, 0, 16)
    val = req.wait(timeout=10.0)
    assert req.test()  # completed requests stay completed
    assert (val == 0).all()
    assert (req.wait() == 0).all()  # wait() is idempotent
    win.free()


def test_per_rank_completion_ordering():
    """Requests to the same target rank complete in issue order: the last
    rput wins, and an rget issued after an rput observes its data."""
    comm = Communicator(2)
    win = Window.allocate(comm, 4096)
    for j in range(100):
        win.rput(np.full(8, j % 251, np.uint8), 1, 64)
    probe = win.rget(1, 64, 8)  # ordered after all 100 rputs
    assert (probe.wait(timeout=10.0) == 99 % 251).all()
    win.flush(1)
    assert (win.get(1, 64, 8) == 99 % 251).all()
    win.free()


def test_flush_completes_only_target_rank_then_flush_all():
    comm = Communicator(3)
    win = Window.allocate(comm, 4096)
    reqs = {r: win.rput(np.full(4, r + 7, np.uint8), r, 0) for r in range(3)}
    win.flush(1)
    assert reqs[1].test()
    assert (win.get(1, 0, 4) == 8).all()
    win.flush_all()
    assert Request.testall(list(reqs.values()))
    for r in range(3):
        assert (win.get(r, 0, 4) == r + 7).all()
    win.free()


def test_raccumulate_request():
    comm = Communicator(1)
    win = Window.allocate(comm, 64)
    win.put(np.array([5], np.int64).view(np.uint8), 0, 0)
    reqs = [win.raccumulate(np.array([v], np.int64), 0, 0, "sum")
            for v in (1, 2, 3)]
    Request.waitall(reqs)
    assert win.get(0, 0, 1, np.int64)[0] == 11
    win.free()


def test_crash_before_flush_loses_unsynced_data(tmp_path):
    """Paper §2.1.1 preserved by the nonblocking layer: a *completed* rput
    lives only in the page cache; disk has it only after the flush."""
    comm = Communicator(1)
    win = Window.allocate(comm, PAGES * 4096, info=storage_info(tmp_path))
    win.rput(np.full(100, 7, np.uint8), 0, 0).wait()
    on_disk = np.fromfile(tmp_path / "w.bin", np.uint8, 100)
    assert not (on_disk == 7).all()  # "crash" now would lose the rput
    win.flush_async(0).wait()
    on_disk = np.fromfile(tmp_path / "w.bin", np.uint8, 100)
    assert (on_disk == 7).all()
    win.free()


def test_flush_async_durable_on_free(tmp_path):
    """free() drains the pipeline: a fire-and-forget flush_async (and the
    rput before it) is on disk once free() returns."""
    comm = Communicator(1)
    win = Window.allocate(comm, PAGES * 4096, info=storage_info(tmp_path))
    win.rput(np.full(64, 42, np.uint8), 0, 2048)
    win.flush_async(0)  # never waited
    win.free()
    raw = np.fromfile(tmp_path / "w.bin", np.uint8, 4096)
    assert (raw[2048:2112] == 42).all()


def test_sync_nonblocking_returns_request(tmp_path):
    comm = Communicator(1)
    win = Window.allocate(comm, PAGES * 4096, info=storage_info(tmp_path))
    win.put(np.full(10, 9, np.uint8), 0, 500)
    assert win.dirty_bytes(0) > 0
    req = win.sync(0, blocking=False)
    assert isinstance(req, Request)
    assert req.wait(timeout=10.0) == 4096  # one dirty page, selectively
    assert win.dirty_bytes(0) == 0
    # clean window: async sync completes with 0 bytes
    assert win.sync(0, blocking=False).wait(timeout=10.0) == 0
    win.free()


def test_concurrent_rput_blocked_by_exclusive_lock():
    """An exclusive lock epoch holds off background rput traffic; the
    request completes only after unlock."""
    comm = Communicator(1)
    win = Window.allocate(comm, 4096)
    win.put(np.full(8, 1, np.uint8), 0, 0)
    win.lock(0, exclusive=True)
    try:
        req = win.rput(np.full(8, 2, np.uint8), 0, 0)
        time.sleep(0.2)  # give the pool time to pick the task up
        assert not req.test()  # blocked on the rank lock
    finally:
        win.unlock(0)
    req.wait(timeout=10.0)
    assert (win.get(0, 0, 8) == 2).all()
    win.free()


def test_request_error_surfaces_at_wait():
    comm = Communicator(1)
    win = Window.allocate(comm, 4096)
    bad = win.rput(np.zeros(16, np.uint8), 0, 4096)  # out of range
    with pytest.raises(IndexError):
        bad.wait(timeout=10.0)
    # the window stays usable, and free() does not re-raise observed errors
    win.rput(np.full(4, 3, np.uint8), 0, 0).wait()
    assert (win.get(0, 0, 4) == 3).all()
    win.free()


def test_fire_and_forget_error_surfaces_at_flush_and_free():
    """A background failure nobody waited on must not vanish: later
    submissions' pruning keeps it tracked, and flush()/free() raise it.
    flush() still completes every other pending request first."""
    comm = Communicator(1)
    win = Window.allocate(comm, 4096)
    win.rput(np.zeros(16, np.uint8), 0, 4096)  # fails on the pool thread
    good = win.rput(np.full(8, 7, np.uint8), 0, 0)  # triggers pruning
    with pytest.raises(IndexError):
        win.flush(0)
    assert good.test()  # the good request completed before the raise
    assert (win.get(0, 0, 8) == 7).all()
    win.free()  # error was observed by flush(): free() is clean
    win2 = Window.allocate(comm, 4096)
    win2.rput(np.zeros(16, np.uint8), 0, 4096)
    with pytest.raises(IndexError):
        win2.free()
    assert win2.freed  # teardown completed despite the surfaced error


def test_mapped_request_shares_observation():
    """Observing an error through a map()-derived request marks the
    registered original too -- free() must not re-raise it."""
    comm = Communicator(1)
    win = Window.allocate(comm, 4096)
    mapped = win.rget(0, 4000, 1000).map(lambda a: a)  # out of range
    with pytest.raises(IndexError):
        mapped.wait(timeout=10.0)
    win.free()  # clean: the underlying request counts as observed


def test_many_threads_issue_requests_concurrently():
    """rput is thread-safe at the issue side too (the train loop and the
    checkpoint manager share windows)."""
    comm = Communicator(2)
    win = Window.allocate(comm, 4096)
    errs = []

    def worker(seed):
        try:
            reqs = [win.rput(np.full(4, (seed + i) % 251, np.uint8),
                             (seed + i) % 2, 4 * seed)
                    for i in range(20)]
            Request.waitall(reqs)
        except BaseException as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    win.flush_all()
    win.free()


def test_ckpt_save_async_pipeline(tmp_path):
    """Back-to-back save_async: the second save waits the first's request,
    manifests commit in order, and wait() surfaces the final state."""
    comm = Communicator(1)
    specs = {"w": ((64, 64), np.float32)}
    cm = CheckpointManager(str(tmp_path), comm, specs)
    req = cm.save_async(1, {"w": np.ones((64, 64), np.float32)})
    assert isinstance(req, Request)
    cm.save_async(2, {"w": np.full((64, 64), 2.0, np.float32)})
    cm.wait()
    assert cm.saves == 2
    r = cm.restore()
    assert r.step == 2 and (r.tree["w"] == 2).all()
    cm.close()


def test_offload_opt_prefetch_matches_blocking(tmp_path):
    """The rget-prefetch / rput-write-behind walk is bit-identical to the
    synchronous walk."""
    rng = np.random.default_rng(1)
    params = {"w": rng.standard_normal((64, 16)).astype(np.float32)}
    shapes = {k: (v.shape, v.dtype) for k, v in params.items()}
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=50,
                      clip_norm=0.0, weight_decay=0.01)
    oo_a = OutOfCoreAdamW(Communicator(1), shapes, str(tmp_path / "a"), cfg,
                          block_bytes=256)
    oo_b = OutOfCoreAdamW(Communicator(1), shapes, str(tmp_path / "b"), cfg,
                          block_bytes=256)
    oo_a.initialize(params)
    oo_b.initialize(params)
    for _ in range(3):
        grads = {k: rng.standard_normal(v.shape).astype(np.float32)
                 for k, v in params.items()}
        out_a = oo_a.update(grads, prefetch=True)
        out_b = oo_b.update(grads, prefetch=False)
        for k in params:
            np.testing.assert_array_equal(out_a[k], out_b[k])
    for k in params:
        np.testing.assert_array_equal(oo_a.masters()[k], oo_b.masters()[k])
    oo_a.free()
    oo_b.free()


def test_dynamic_window_requests(tmp_path):
    from repro.core import alloc_mem
    comm = Communicator(1)
    seg = alloc_mem(1 << 16, info=storage_info(tmp_path, "dyn.bin"))
    win = Window.create_dynamic(comm)
    h = win.attach(0, seg)
    win.rput(np.full(32, 5, np.uint8), 0, 0, handle=h)
    got = win.rget(0, 0, 32, handle=h).wait(timeout=10.0)
    assert (got == 5).all()
    assert win.flush_async(0).wait(timeout=10.0) > 0
    win.free()
