"""Window semantics: one-sided ops, epochs, sync, persistence, combined."""

import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Communicator, Window, alloc_mem


def mk_storage_info(tmp_path, name="w.bin", **extra):
    info = {"alloc_type": "storage",
            "storage_alloc_filename": str(tmp_path / name)}
    info.update({k: str(v) for k, v in extra.items()})
    return info


def test_put_get_roundtrip(tmp_path):
    comm = Communicator(4)
    win = Window.allocate(comm, 4096, info=mk_storage_info(tmp_path))
    data = np.arange(50, dtype=np.int64)
    win.put(data.view(np.uint8), 3, 128)
    got = win.get(3, 128, 50, np.int64)
    assert (got == data).all()
    win.free()


def test_memory_window_default():
    comm = Communicator(2)
    win = Window.allocate(comm, 1024)
    assert win.flavor == "memory"
    win.put(np.full(8, 9, np.uint8), 1, 0)
    assert (win.get(1, 0, 8) == 9).all()
    assert win.sync() == 0  # nothing to persist
    win.free()


@given(op=st.sampled_from(["sum", "prod", "min", "max", "replace"]),
       vals=st.lists(st.integers(-100, 100), min_size=1, max_size=8))
@settings(deadline=None, max_examples=30)
def test_accumulate_matches_numpy(op, vals):
    comm = Communicator(2)
    win = Window.allocate(comm, 64)
    init = np.array([3], np.int64)
    win.put(init.view(np.uint8), 0, 0)
    acc = init.copy()
    npop = {"sum": np.add, "prod": np.multiply, "min": np.minimum,
            "max": np.maximum}.get(op)
    for v in vals:
        arr = np.array([v], np.int64)
        win.accumulate(arr, 0, 0, op=op)
        acc = npop(acc, arr) if npop else arr.copy()
    assert win.get(0, 0, 1, np.int64)[0] == acc[0]
    win.free()


def test_fetch_and_op_and_cas():
    comm = Communicator(1)
    win = Window.allocate(comm, 64)
    win.put(np.array([10], np.int64).view(np.uint8), 0, 0)
    old = win.fetch_and_op(5, 0, 0, "sum")
    assert old == 10
    assert win.get(0, 0, 1, np.int64)[0] == 15
    old = win.compare_and_swap(99, 15, 0, 0)
    assert old == 15 and win.get(0, 0, 1, np.int64)[0] == 99
    old = win.compare_and_swap(1, 15, 0, 0)  # compare fails
    assert old == 99 and win.get(0, 0, 1, np.int64)[0] == 99
    win.free()


def test_persistence_requires_sync(tmp_path):
    """Paper §2.1.1: ops touch only the page-cache copy; storage is undefined
    until MPI_Win_sync."""
    comm = Communicator(1)
    path = tmp_path / "p.bin"
    # 16 pages: a 100-byte write stays under vm.dirty_ratio (no auto flush)
    win = Window.allocate(comm, 16 * 4096, info={"alloc_type": "storage",
                                                 "storage_alloc_filename": str(path)})
    win.put(np.full(100, 7, np.uint8), 0, 0)
    on_disk = np.fromfile(path, np.uint8, 100)
    assert not (on_disk == 7).all()          # not yet persisted
    win.sync(0)
    on_disk = np.fromfile(path, np.uint8, 100)
    assert (on_disk == 7).all()              # persisted after sync
    win.free()


def test_shared_file_offsets(tmp_path):
    """Paper Fig. 4: several ranks map one file at per-rank offsets."""
    comm = Communicator(3)
    path = tmp_path / "shared.bin"
    win = Window.allocate(comm, 1024, info={"alloc_type": "storage",
                                            "storage_alloc_filename": str(path)},
                          shared_file=True)
    for r in range(3):
        win.put(np.full(8, r + 1, np.uint8), r, 0)
    win.sync()
    win.free()
    raw = np.fromfile(path, np.uint8)
    assert raw[0] == 1 and raw[1024] == 2 and raw[2048] == 3


def test_exclusive_lock_epoch(tmp_path):
    comm = Communicator(2)
    win = Window.allocate(comm, 128)
    win.lock(0, exclusive=True)
    win.put(np.full(4, 1, np.uint8), 0, 0)
    win.unlock(0)
    win.lock(0)          # shared epoch
    _ = win.get(0, 0, 4)
    win.unlock(0)
    with pytest.raises(Exception):
        win.unlock(0)    # unmatched unlock
    win.free()


def test_dynamic_window_attach_detach(tmp_path):
    """Paper Listing 3: hints passed to MPI_Alloc_mem, then attach."""
    comm = Communicator(1)
    seg = alloc_mem(256, info=mk_storage_info(tmp_path, "dyn.bin"))
    win = Window.create_dynamic(comm)
    h = win.attach(0, seg)
    win.put(np.full(16, 5, np.uint8), 0, 0, handle=h)
    assert (win.get(0, 0, 16, handle=h) == 5).all()
    assert win.sync(0) > 0
    win.detach(0, h)
    with pytest.raises(Exception):
        win.get(0, 0, 16, handle=h)
    win.free()


def test_unlink_hint_removes_file(tmp_path):
    comm = Communicator(1)
    path = tmp_path / "tmpwin.bin"
    win = Window.allocate(comm, 4096, info={
        "alloc_type": "storage", "storage_alloc_filename": str(path),
        "storage_alloc_unlink": "true"})
    win.put(np.full(8, 1, np.uint8), 0, 0)
    assert path.exists()
    win.free()
    assert not path.exists()


def test_discard_hint_skips_final_sync(tmp_path):
    comm = Communicator(1)
    path = tmp_path / "d.bin"
    win = Window.allocate(comm, 4096, info={
        "alloc_type": "storage", "storage_alloc_filename": str(path),
        "storage_alloc_discard": "true"})
    win.put(np.full(64, 9, np.uint8), 0, 0)
    win.free()  # discard: no flush on free
    raw = np.fromfile(path, np.uint8, 64)
    assert not (raw == 9).all()


def test_combined_window_split(tmp_path):
    comm = Communicator(1)
    info = mk_storage_info(tmp_path, "c.bin",
                           storage_alloc_factor="0.5")
    win = Window.allocate(comm, 8192, info=info)
    assert win.flavor == "combined"
    data = np.arange(8192 % 251, dtype=np.uint8)
    # write spanning the memory/storage boundary
    span = np.arange(200, dtype=np.uint8)
    win.put(span, 0, 4000)
    assert (win.get(0, 4000, 200) == span).all()
    # only the storage half persists
    flushed = win.sync(0)
    assert 0 < flushed <= 4200
    win.free()


def test_combined_auto_factor(tmp_path):
    comm = Communicator(1)
    info = mk_storage_info(tmp_path, "a.bin", storage_alloc_factor="auto")
    win = Window.allocate(comm, 1 << 20, info=info, memory_budget=1 << 18)
    seg = win.segments[0]
    assert seg.mem_bytes == 1 << 18 and seg.sto_bytes == (1 << 20) - (1 << 18)
    win.free()


def test_storage_first_order(tmp_path):
    comm = Communicator(1)
    info = mk_storage_info(tmp_path, "o.bin", storage_alloc_factor="0.25",
                           storage_alloc_order="storage_first")
    win = Window.allocate(comm, 4096, info=info)
    win.put(np.full(4096, 3, np.uint8), 0, 0)
    assert win.sync(0) > 0  # storage part at the front
    win.free()


@settings(deadline=None, max_examples=15)
@given(writes=st.lists(st.tuples(st.integers(0, 8000),
                                 st.integers(1, 500),
                                 st.integers(0, 255)),
                       min_size=1, max_size=12),
       factor=st.sampled_from(["0.0", "0.3", "0.5", "0.9", "1.0"]))
def test_combined_window_equals_memory_model(tmp_path_factory, writes, factor):
    """A combined window behaves exactly like one flat byte space."""
    d = tmp_path_factory.mktemp("cmb")
    comm = Communicator(1)
    win = Window.allocate(comm, 8192, info={
        "alloc_type": "storage", "storage_alloc_filename": str(d / "x.bin"),
        "storage_alloc_factor": factor})
    model = np.zeros(8192, np.uint8)
    for off, n, val in writes:
        n = min(n, 8192 - off)
        if n <= 0:
            continue
        win.put(np.full(n, val, np.uint8), 0, off)
        model[off:off + n] = val
    got = win.get(0, 0, 8192)
    assert (got == model).all()
    win.free()


def test_free_idempotent(tmp_path):
    """Double free is a silent no-op (MPI_Win_free is called once, but
    teardown paths -- __exit__, close(), error handlers -- may overlap)."""
    comm = Communicator(2)
    win = Window.allocate(comm, 4096, info=mk_storage_info(tmp_path))
    win.put(np.full(16, 4, np.uint8), 0, 0)
    win.free()
    assert win.freed
    win.free()  # second free: no error, no re-close
    assert comm.active_windows() == 0
    # and the communicator still closes cleanly afterwards
    comm.close()


def test_free_idempotent_with_context_manager(tmp_path):
    comm = Communicator(1)
    with Window.allocate(comm, 1024, info=mk_storage_info(tmp_path)) as win:
        win.free()  # explicit free inside the with: __exit__ must not raise
    assert win.freed
    comm.close()
