"""Joined tcp fleet: SPMD across machines (exercised on loopback).

The spawned-fleet/driver-origin half of the tcp backend rides the
transport conformance suite in ``test_transport.py``; this module covers
what only a *joined* fleet can show: externally-launched processes that
each ARE one rank, bootstrapping from a ``REPRO_HOSTS`` roster, serving
each other over authenticated framed TCP, running collectives through the
rank-0 round board -- and leaving the same on-disk layout as every other
backend.

Fleet entry functions are module-level so the spawn start method can
pickle them by reference (same pattern as ``test_spmd.py``).
"""

from __future__ import annotations

import multiprocessing
import os
import socket
import threading

import numpy as np
import pytest


def _loopback_ok() -> bool:
    try:
        srv = socket.create_server(("127.0.0.1", 0))
        srv.close()
        return True
    except OSError:  # pragma: no cover - sandboxed/socket-less platforms
        return False


pytestmark = pytest.mark.skipif(not _loopback_ok(),
                                reason="loopback sockets unavailable")

_NRANKS = 2


def _pick_ports(n: int) -> list[int]:
    socks = [socket.create_server(("127.0.0.1", 0)) for _ in range(n)]
    ports = [s.getsockname()[1] for s in socks]
    for s in socks:
        s.close()
    return ports


def _fleet_entry(rank: int, hosts: list[str], conn, base: str) -> None:
    """One externally-launched fleet rank: env bootstrap, a storage window
    with one-sided traffic both ways, collectives, durable sync."""
    os.environ["REPRO_TRANSPORT"] = "tcp"
    os.environ["REPRO_HOSTS"] = ",".join(hosts)
    os.environ["REPRO_NRANKS"] = str(_NRANKS)
    os.environ["REPRO_RANK"] = str(rank)
    try:
        from repro.core import Communicator, Window

        comm = Communicator.from_env()
        out = {"kind": comm.transport.kind, "rank": comm.rank,
               "size": comm.size}
        peer = 1 - comm.rank
        win = Window.allocate(comm, 4096, info={
            "alloc_type": "storage",
            "storage_alloc_filename": os.path.join(base, "w.bin")})
        try:
            win.put(np.full(64, comm.rank + 1, np.uint8), comm.rank, 0)
            win.put(np.full(8, 0xB0 + comm.rank, np.uint8), peer, 128)
            comm.barrier()  # both ranks' puts are complete and visible
            out["peer_fill"] = int(win.get(peer, 0, 1)[0])
            out["from_peer"] = int(win.get(comm.rank, 128, 1)[0])
            out["sum"] = comm.allreduce(float(comm.rank + 1))
            out["bc"] = comm.bcast("root-says" if comm.rank == 0 else None,
                                   root=0)
            sub = comm.split(color=0, ranks=[0, 1])
            out["sub_sum"] = sub.allreduce(10.0 * (comm.rank + 1))
            sub.close()
            win.sync(comm.rank)
            out["net"] = comm.transport.net_stats_snapshot()
            comm.barrier()  # nobody frees while the peer still reads
        finally:
            win.free()
            comm.close()
        conn.send(("ok", out))
    except BaseException as e:  # surface the failure to the parent
        conn.send(("err", f"{type(e).__name__}: {e}"))
    finally:
        conn.close()


def test_tcp_joined_fleet_roster_bootstrap(tmp_path):
    """Two externally-launched ranks join via REPRO_HOSTS, exchange
    one-sided traffic, agree on collectives, and leave the standard
    ``<file>.<rank>`` layout on disk."""
    ctx = multiprocessing.get_context("spawn")
    hosts = [f"127.0.0.1:{p}" for p in _pick_ports(_NRANKS)]
    pipes, procs = [], []
    for r in range(_NRANKS):
        parent, child = ctx.Pipe()
        p = ctx.Process(target=_fleet_entry,
                        args=(r, hosts, child, str(tmp_path)),
                        name=f"fleet-{r}")
        p.start()
        child.close()
        pipes.append(parent)
        procs.append(p)
    results = {}
    try:
        for r, conn in enumerate(pipes):
            assert conn.poll(120), f"rank {r} produced no result"
            status, payload = conn.recv()
            assert status == "ok", f"rank {r} failed: {payload}"
            results[r] = payload
    finally:
        for p in procs:
            p.join(timeout=30)
            if p.is_alive():  # pragma: no cover - hung fleet
                p.terminate()
    assert all(p.exitcode == 0 for p in procs)

    for r in range(_NRANKS):
        out = results[r]
        assert out["kind"] == "tcp" and out["rank"] == r
        assert out["peer_fill"] == (1 - r) + 1   # read the peer's fill
        assert out["from_peer"] == 0xB0 + (1 - r)  # the peer's put landed
        assert out["sum"] == pytest.approx(3.0)  # 1 + 2, both origins
        assert out["bc"] == "root-says"
        assert out["sub_sum"] == pytest.approx(30.0)
        assert out["net"]["bytes_tx"] > 0 and out["net"]["frames_rx"] > 0

    # the on-disk layout matches every other backend: per-rank files with
    # the rank's own fill and the peer's one-sided put, both synced
    for r in range(_NRANKS):
        disk = np.fromfile(str(tmp_path / f"w.bin.{r}"), dtype=np.uint8)
        assert (disk[:64] == r + 1).all()
        assert disk[128] == 0xB0 + (1 - r)


def test_tcp_joined_probe_and_respawn_contract(monkeypatch, tmp_path):
    """A joined fleet has no spawner: probe of an unreachable peer fails
    fast (bounded by the probe knob) and respawn_rank tells the operator
    to restart the external process, naming the address."""
    from repro.core.transport import TransportError
    from repro.core.transport.tcp import TcpPeerTransport

    monkeypatch.setenv("REPRO_TCP_PROBE_TIMEOUT", "1")
    monkeypatch.setenv("REPRO_TCP_CONNECT_TIMEOUT", "1")
    me, dead = _pick_ports(2)
    t = TcpPeerTransport(2, 0, [f"127.0.0.1:{me}", f"127.0.0.1:{dead}"])
    try:
        assert t.probe(0) is True          # self: always alive
        assert t.probe(1) is False         # nothing listens there
        with pytest.raises(TransportError, match="launched externally"):
            t.respawn_rank(1)
        with pytest.raises(TransportError, match="cannot respawn itself"):
            t.respawn_rank(0)
    finally:
        t.shutdown()


def test_tcp_roster_length_must_match_size():
    from repro.core.transport.tcp import TcpPeerTransport
    with pytest.raises(ValueError, match="one host:port per rank"):
        TcpPeerTransport(3, 0, ["127.0.0.1:1", "127.0.0.1:2"])
    with pytest.raises(ValueError, match="expected host:port"):
        TcpPeerTransport(1, 0, ["no-port-here"])


def test_round_board_matches_positionally_and_caches():
    """The rank-0 board pairs the pos-th round per group and keeps
    completed rounds readable (a restarted rank replays into the cache)."""
    from repro.core.transport.tcp import _RoundBoard

    board = _RoundBoard()
    got = {}

    def rank1():
        got[1] = board.contribute(1, (0, 1), 0, ("allreduce", "sum", 10),
                                  timeout=30.0)

    th = threading.Thread(target=rank1)
    th.start()
    got[0] = board.contribute(0, (0, 1), 0, ("allreduce", "sum", 32),
                              timeout=30.0)
    th.join(timeout=30)
    assert got[0] == got[1] == {0: ("allreduce", "sum", 32),
                                1: ("allreduce", "sum", 10)}
    # replay after completion: served from the cache, no new round opened
    again = board.contribute(1, (0, 1), 0, ("allreduce", "sum", 10),
                             timeout=1.0)
    assert again == got[0]
    # a missing participant times out with a useful message
    from repro.core.transport import TransportError
    with pytest.raises(TransportError, match="missing contributions"):
        board.contribute(0, (0, 1), 1, ("barrier",), timeout=0.2)
