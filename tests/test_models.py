"""Per-arch smoke tests + block-level properties.

Every assigned architecture: reduced config, one forward/train step on CPU,
output shapes + finite values; prefill/decode cache consistency.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_config
from repro.models import (init_cache_specs, init_params, make_decode_fn,
                          make_loss_fn, make_prefill_fn, param_specs)

RNG = jax.random.PRNGKey(0)


def mk_batch(cfg, B, S, with_targets=True):
    St = S - cfg.img_tokens if cfg.frontend == "vlm_stub" else S
    toks = jax.random.randint(RNG, (B, St), 0, cfg.vocab).astype(jnp.int32)
    b = {"inputs": toks}
    if with_targets:
        b["targets"] = toks
    if cfg.frontend == "vlm_stub":
        b["patches"] = jax.random.normal(RNG, (B, cfg.img_tokens, cfg.d_model),
                                         jnp.bfloat16)
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(RNG, (B, 16, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    """Reduced config: one train step (forward+backward+update direction)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(param_specs(cfg), RNG)
    batch = mk_batch(cfg, 2, 24)
    loss_fn = make_loss_fn(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["ntok"]) > 0
    gn = sum(float(jnp.abs(g).sum()) for g in grads.values())
    assert np.isfinite(gn) and gn > 0, f"{arch}: dead gradients"
    for k, g in grads.items():
        assert g.shape == params[k].shape


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_prefill_decode_consistency(arch):
    """decode(prefill(S), token_S) == prefill(S+1) last logits."""
    cfg = get_config(arch, smoke=True)
    params = init_params(param_specs(cfg), jax.random.PRNGKey(1))
    B, S = 2, 17
    St = S - cfg.img_tokens if cfg.frontend == "vlm_stub" else S
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, St + 1), 0,
                              cfg.vocab).astype(jnp.int32)
    enc_len = 8 if cfg.is_encdec else 0

    def batch(n):
        b = {"inputs": toks[:, :n]}
        if cfg.frontend == "vlm_stub":
            b["patches"] = jax.random.normal(
                jax.random.PRNGKey(3), (B, cfg.img_tokens, cfg.d_model),
                jnp.bfloat16)
        if cfg.is_encdec:
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(4), (B, enc_len, cfg.d_model), jnp.bfloat16)
        return b

    cache_specs = init_cache_specs(cfg, B, S + 1, enc_len)

    def zero_cache():
        return {k: jnp.zeros(v.shape, jnp.dtype(v.dtype))
                for k, v in cache_specs.items()}

    prefill = jax.jit(make_prefill_fn(cfg))
    decode = jax.jit(make_decode_fn(cfg))
    _, cache = prefill(params, batch(St), zero_cache())
    la, _ = decode(params, cache, toks[:, St:St + 1], jnp.int32(S))
    lb, _ = prefill(params, batch(St + 1), zero_cache())
    a = np.asarray(la[:, 0], np.float32)
    b = np.asarray(lb[:, 0], np.float32)
    err = np.abs(a - b).max() / max(1e-6, np.abs(b).max())
    # MoE archs tolerate capacity-dropping differences between batch sizes
    tol = 0.08 if cfg.n_experts else 0.02
    assert err < tol, (arch, err)


def test_moe_conserves_token_mass():
    """Router gates renormalize: combine weights per token sum to 1."""
    from repro.models.moe import moe_mlp
    cfg = get_config("deepseek-v2-236b", smoke=True)
    p = init_params({k: v for k, v in param_specs(cfg).items()
                     if k.startswith("g1/p0/")}, RNG)
    p = {k.removeprefix("g1/p0/"): v[0] for k, v in p.items()}  # unstack
    pm = {k: v for k, v in p.items()
          if k in ("router", "we_up", "we_gate", "we_down", "ws_up",
                   "ws_gate", "ws_down")}
    x = jax.random.normal(RNG, (2, 16, cfg.d_model), jnp.bfloat16) * 0.3
    y, aux = moe_mlp(cfg, pm, x, capacity=64)  # ample capacity: no drops
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0.0


@settings(deadline=None, max_examples=10)
@given(S=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_equals_sequential(S, chunk):
    from repro.kernels.ref import ssd_scan_ref
    from repro.models.ssm import ssd_chunked
    B, H, P, N = 1, 2, 8, 4
    k = jax.random.PRNGKey(S)
    x = jax.random.normal(k, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(k, 3), (B, S, H, N)) * 0.4
    C = jax.random.normal(jax.random.fold_in(k, 4), (B, S, H, N)) * 0.4
    y, h = ssd_chunked(x, dt, A, Bm, C, chunk=chunk)
    want = ssd_scan_ref(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
                        Bm.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               atol=1e-4, rtol=1e-3)


@settings(deadline=None, max_examples=10)
@given(S=st.integers(2, 50))
def test_rglru_associative_scan_equals_sequential(S):
    from repro.kernels.ref import rg_lru_ref
    from repro.models.griffin import rg_lru
    W = 8
    k = jax.random.PRNGKey(S + 100)
    p = {
        "w_i": jax.random.normal(k, (W, W)) * 0.2,
        "b_i": jnp.zeros(W), "w_r": jax.random.normal(k, (W, W)) * 0.2,
        "b_r": jnp.zeros(W), "lam": jnp.ones(W),
    }
    x = jax.random.normal(jax.random.fold_in(k, 1), (2, S, W)) * 0.5
    y, h_last = rg_lru(p, x)
    # reference: sequential recurrence with the same gates
    import repro.models.griffin as G
    i_t, log_a = G._gates(p, x)
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * i_t * x
    want = rg_lru_ref(a, gx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want[:, -1]),
                               atol=1e-5)


@settings(deadline=None, max_examples=8)
@given(S=st.integers(8, 64), qb=st.sampled_from([8, 16]),
       kb=st.sampled_from([8, 32]), window=st.sampled_from([None, 16]))
def test_blockwise_equals_full_attention(S, qb, kb, window):
    from repro.models.attention import blockwise_attention, full_attention
    B, H, K, d = 1, 2, 1, 16
    k = jax.random.PRNGKey(S)
    q = jax.random.normal(k, (B, S, H, d)) * 0.4
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, K, d)) * 0.4
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, K, d)) * 0.4
    a = blockwise_attention(q, kk, v, causal=True, window=window,
                            q_block=qb, kv_block=kb)
    b = full_attention(q, kk, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-5, rtol=1e-4)


def test_mla_latent_cache_is_small():
    """MLA's point: latent cache (r + rope) << full K/V cache."""
    cfg = get_config("deepseek-v2-236b")
    specs = init_cache_specs(cfg, 1, 1024)
    latent = sum(np.prod(s.shape) * np.dtype(jnp.dtype(s.dtype)).itemsize
                 for k, s in specs.items())
    full_kv = (cfg.n_layers * 2 * 1024 * cfg.n_heads *
               (cfg.nope_head_dim + cfg.rope_head_dim) * 2)
    assert latent < full_kv / 10  # >10x compression


def test_local_attn_ring_cache_is_bounded():
    cfg = get_config("recurrentgemma-2b")
    specs = init_cache_specs(cfg, 1, 524288)
    for k, s in specs.items():
        if k.endswith("/k") or k.endswith("/v"):
            assert s.shape[2] == cfg.window  # ring buffer, not 500k


def test_param_count_sane():
    for arch, approx_b in [("qwen2-72b", 72e9), ("gemma-7b", 8.5e9),
                           ("internlm2-1.8b", 1.9e9), ("mamba2-2.7b", 2.7e9),
                           ("deepseek-v2-236b", 236e9),
                           ("llama4-maverick-400b-a17b", 400e9)]:
        cfg = get_config(arch)
        specs = param_specs(cfg)
        n = sum(int(np.prod(s.shape)) for s in specs.values())
        assert 0.75 * approx_b < n < 1.35 * approx_b, (arch, n)


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    active = cfg.param_count(active_only=True)
    total = cfg.param_count()
    assert active < total / 8  # a17b-style activation ratio
