"""Long-context serving with window-backed resumable sessions.

A recurrent (RG-LRU hybrid) model decodes with O(1) state; the decode state
lives in a *combined* storage window (factor 0.5: half pinned, half behind
the page cache).  The session survives an engine restart -- the serving
analogue of the paper's checkpoint/restart story.

Run:  PYTHONPATH=src python examples/long_context_serve.py
"""

import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Communicator
from repro.models import init_cache_specs, init_params, param_specs
from repro.serve import Engine, SessionStore

tmp = tempfile.mkdtemp(prefix="repro_serve_")
cfg = get_config("recurrentgemma-2b", smoke=True)
params = init_params(param_specs(cfg), jax.random.PRNGKey(0))

B, PROMPT, STEPS, MAX_LEN = 2, 8, 12, 64
toks = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                          cfg.vocab).astype("int32")

store = SessionStore(Communicator(1), f"{tmp}/session.bin",
                     init_cache_specs(cfg, B, MAX_LEN), factor="0.5")

# -- serve 6 tokens, persist the session, drop the engine ---------------------
eng = Engine(cfg, params, batch=B, max_len=MAX_LEN, session=store)
nxt = eng.prefill({"inputs": toks})
out = [nxt]
for _ in range(5):
    nxt = eng.step(nxt)
    out.append(nxt)
eng.generated = out
flushed = eng.save_session()
print(f"session persisted ({flushed >> 10} KiB flushed), killing engine")
del eng

# -- a fresh engine resumes exactly where the old one stopped ------------------
eng2 = Engine(cfg, params, batch=B, max_len=MAX_LEN, session=store)
eng2.load_session()
print(f"resumed at position {eng2.pos}")
for _ in range(STEPS - 6):
    nxt = eng2.step(nxt)
    out.append(nxt)
resumed = np.stack(out, axis=1)

# -- reference: one uninterrupted generation ------------------------------------
eng3 = Engine(cfg, params, batch=B, max_len=MAX_LEN)
ref = eng3.generate({"inputs": toks}, STEPS)
assert (resumed == ref).all(), "resumed session must match uninterrupted run"
print("resumed generation is bit-exact:", resumed[0].tolist())
store.free()
print("done")
