"""End-to-end driver: train a ~100M-parameter LM with windowed checkpoints.

Demonstrates the full stack on real hardware (this CPU, or a TPU host):
synthetic sharded data pipeline -> pjit-able train step -> AdamW ->
transparent A/B checkpointing into storage windows -> kill -> restart ->
bit-exact continuation.

Default invocation is sized for a laptop-class smoke (a few minutes):
    PYTHONPATH=src python examples/train_e2e.py --steps 40
The full deliverable run:
    PYTHONPATH=src python examples/train_e2e.py --params 100m --steps 300
"""

import argparse
import dataclasses
import os

import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM, make_batch_iter
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, TrainConfig, Trainer


def model_100m() -> ModelConfig:
    """~100M-parameter dense LM (internlm2-style blocks)."""
    return dataclasses.replace(
        get_config("internlm2-1.8b"),
        name="dense-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=2, head_dim=64, d_ff=2560, vocab=32000, remat="none")


def model_tiny() -> ModelConfig:
    return get_config("internlm2-1.8b", smoke=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", choices=("tiny", "100m"), default="tiny")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="simulate a crash after N steps, then restart")
    ap.add_argument("--mode", choices=("fused", "offload"), default="fused")
    ap.add_argument("--compression", action="store_true")
    args = ap.parse_args()

    cfg = model_100m() if args.params == "100m" else model_tiny()
    from repro.models import param_specs
    n_params = sum(int(np.prod(s.shape)) for s in param_specs(cfg).values())
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, microbatches=1, mode=args.mode,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     ckpt_async=True, compression=args.compression,
                     log_every=5)

    ds = SyntheticLM(cfg, batch=args.batch, seq=args.seq, microbatches=1)

    class It:
        def __init__(self, start=0):
            self.step = start
        def __next__(self):
            b = ds.batch_at(self.step)
            self.step += 1
            return b

    if args.kill_at:
        print(f"-- phase 1: training to step {args.kill_at}, then 'crash' --")
        tr = Trainer(cfg, opt, tc)
        tr.run(It(), stop_after=args.kill_at)
        tr._ckpt.wait() if tr._ckpt else None
        print("-- crash! restarting from the window checkpoint --")
        tr2 = Trainer(cfg, opt, tc)
        start = (args.kill_at // args.ckpt_every) * args.ckpt_every
        tr2.run(It(start))
        print(f"resumed at step {start}, finished at {args.steps}")
        tr2.close()
    else:
        tr = Trainer(cfg, opt, tc)
        tr.run(It())
        losses = [m["loss"] for m in tr.metrics_log]
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
        tr.close()


if __name__ == "__main__":
    main()
