"""Out-of-core DHT (paper §3.4): the table exceeds the memory budget.

The combined window's ``factor='auto'`` pins what fits and spills the rest
behind the user-level page cache -- the application code never changes.
Neither does it change with the transport: under ``REPRO_TRANSPORT=mp``
the four ranks are real worker processes (segments owned by them, RMA
serviced by their progress threads) and the numbers must come out the same.
(The ``__main__`` guard is what makes that safe: spawned workers re-import
this file.)

Run:  PYTHONPATH=src python examples/out_of_core_dht.py
      REPRO_TRANSPORT=mp REPRO_NRANKS=4 PYTHONPATH=src python examples/out_of_core_dht.py
"""

import tempfile
import time

import numpy as np

from repro.core import Communicator, DistributedHashTable

LV = 1 << 14          # 16k slots/rank -> ~7.9 MiB/rank with the heap
BUDGET = 1 << 20      # pretend each rank only has 1 MiB of memory


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="repro_ooc_")
    comm = Communicator.from_env(4)
    print(f"transport={comm.transport.kind} ranks={comm.size}")

    dht = DistributedHashTable(comm, LV, heap_factor=4, info={
        "alloc_type": "storage",
        "storage_alloc_filename": f"{tmp}/dht.bin",
        "storage_alloc_factor": "auto",          # spill beyond the budget
    }, memory_budget=BUDGET)

    seg = dht.win.segments[0]
    print(f"per-rank segment: {seg.size >> 10} KiB "
          f"({seg.mem_bytes >> 10} KiB pinned, {seg.sto_bytes >> 10} KiB spilled)")

    rng = np.random.default_rng(0)
    n = int(LV * 4 * 0.8 * 0.25)
    t0 = time.perf_counter()
    for k in rng.integers(1, 1 << 48, n):
        dht.insert(int(k), 1, op="sum")
    dt = time.perf_counter() - t0
    print(f"inserted {n} keys at {n / dt:.0f}/s (out-of-core)")

    t0 = time.perf_counter()
    flushed = dht.sync()
    print(f"checkpoint: {flushed >> 20} MiB flushed in "
          f"{time.perf_counter() - t0:.2f}s")

    hits = sum(dht.lookup(int(k)) is not None
               for k in rng.integers(1, 1 << 48, 100))
    print(f"probe: {hits}/100 random keys found (expected ~0 misses on inserted)")
    dht.free()
    comm.close()
    print("done")


if __name__ == "__main__":
    main()
