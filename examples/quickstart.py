"""Quickstart: the MPI-windows-on-storage API in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core import (Communicator, DistributedHashTable, Window,
                        WindowedPyTree)

tmp = tempfile.mkdtemp(prefix="repro_quickstart_")
comm = Communicator(4)  # four logical ranks

# -- 1. a storage window: same API as a memory window, hints decide the tier --
info = {
    "alloc_type": "storage",                       # paper Listing 1
    "storage_alloc_filename": f"{tmp}/win.bin",
    "storage_alloc_unlink": "false",
}
win = Window.allocate(comm, 1 << 20, info=info)

# one-sided ops: even ranks write into odd ranks' windows (paper Listing 1)
for rank in range(0, comm.size, 2):
    for drank in range(1, comm.size, 2):
        k = np.asarray([rank + 42], np.int64)
        with win.locked(drank):   # scoped epoch: unlocks on every path
            win.put(k.view(np.uint8), drank, 0)

print("rank1 sees:", win.get(1, 0, 1, np.int64)[0])

# persistence is explicit: put touches the page cache; sync flushes dirty
# blocks (selective -- a second sync is free)
print("first sync flushed:", win.sync(1), "bytes")
print("second sync flushed:", win.sync(1), "bytes (already clean)")
win.free()

# -- 2. combined allocation: one address space, half memory half storage ----
info = {
    "alloc_type": "storage",
    "storage_alloc_filename": f"{tmp}/combined.bin",
    "storage_alloc_factor": "0.5",                 # paper Listing 2
}
win = Window.allocate(comm, 1 << 20, info=info)
win.put(np.full(1 << 20, 7, np.uint8), 0, 0)       # spans both tiers
print("combined read ok:", (win.get(0, 0, 1 << 20) == 7).all())
win.free()

# -- 3. out-of-core auto factor: spill exactly what exceeds the budget -------
info["storage_alloc_factor"] = "auto"
info["storage_alloc_filename"] = f"{tmp}/auto.bin"
win = Window.allocate(comm, 1 << 20, info=info, memory_budget=1 << 18)
seg = win.segments[0]
print(f"auto split: {seg.mem_bytes >> 10} KiB memory, "
      f"{seg.sto_bytes >> 10} KiB storage")
win.free()

# -- 4. tensors in windows: the JAX bridge ------------------------------------
tree = WindowedPyTree.from_tree(comm, {
    "weights": np.random.default_rng(0).standard_normal((64, 64)).astype(np.float32),
}, info={"alloc_type": "storage",
         "storage_alloc_filename": f"{tmp}/params.bin"})
w = tree.array("weights")
w.update_blocks(lambda blk: blk * 0.5)             # streamed, out-of-core
print("windowed tensor mean:", float(w.get().mean()))
tree.free()

# -- 5. a one-sided DHT on storage (paper 3.3) -------------------------------
dht = DistributedHashTable(comm, 1 << 10, info={
    "alloc_type": "storage", "storage_alloc_filename": f"{tmp}/dht.bin"})
for key in range(100):
    dht.insert(key, key * key)
print("dht[7] =", dht.lookup(7))
print("checkpoint flushed:", dht.sync(), "bytes")
dht.free()

print("quickstart done; files under", tmp)
