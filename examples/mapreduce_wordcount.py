"""MapReduce WordCount with transparent checkpointing (paper §3.5.2).

A crash mid-job loses nothing: the reduce state and per-rank progress live
in storage windows synced after every Map task; the restarted job resumes
from the first unfinished task.  The storage-window file layout is
transport-invariant, so the same run works (and recovers) with the ranks
as real worker processes: ``REPRO_TRANSPORT=mp REPRO_NRANKS=4``.  (The
``__main__`` guard is what makes that safe: spawned workers re-import this
file.)

Run:  PYTHONPATH=src python examples/mapreduce_wordcount.py
      REPRO_TRANSPORT=mp REPRO_NRANKS=4 PYTHONPATH=src python examples/mapreduce_wordcount.py
"""

import tempfile

import numpy as np

from repro.core import Communicator, MapReduce1S
from repro.core.mapreduce import stable_word_key, wordcount_map


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="repro_mr_")
    words = "the quick brown fox jumps over lazy dog lorem ipsum".split()
    rng = np.random.default_rng(0)
    tasks = [" ".join(rng.choice(words, 500)) for _ in range(16)]

    info = {"alloc_type": "storage", "storage_alloc_filename": f"{tmp}/mr.bin"}

    # -- phase 1: run a few tasks, then "crash" -------------------------------
    comm = Communicator.from_env(4)
    print(f"transport={comm.transport.kind} ranks={comm.size}")
    mr = MapReduce1S(comm, 1 << 10, info=info)
    my0 = mr._tasks_of(0, len(tasks))
    for pos in range(2):  # rank 0 finishes only 2 tasks
        for k, v in wordcount_map(tasks[my0[pos]]).items():
            mr.table.insert(k, v, op="sum")
        mr._commit_task(0, pos)
    print(f"crash after {mr.completed_tasks()} committed tasks "
          f"({mr.ckpt_bytes >> 10} KiB checkpointed so far)")

    # -- phase 2: resume -- the progress window knows where everyone stopped --
    mr.run(tasks)
    result = mr.result()

    expect = {}
    for t in tasks:
        for k, v in wordcount_map(t).items():
            expect[k] = expect.get(k, 0) + v
    assert result == expect, "resumed result must equal a clean run"
    print(f"wordcount ok: 'the' -> {result[stable_word_key('the')]}")
    print(f"transparent checkpoints: {mr.ckpt_count} syncs, "
          f"{mr.ckpt_bytes >> 10} KiB total (selective)")
    mr.free()
    comm.close()
    print("done")


if __name__ == "__main__":
    main()
