"""SPMD training with mid-run rank death and exact resume.

Drives the same path as ``python -m repro.launch.train --spmd``: N worker
ranks each run the Trainer themselves (their own device diffs, their own
mirrored writes, their own checkpoint manifests) while this process is
only a launcher/monitor.  Two failures are exercised:

1. **Rank death**: one rank is SIGKILLed after its first checkpoint
   commits; ``rebuild_rank`` respawns it, and the respawn re-enters the
   application entry point, restores from its *own* manifest, and resumes
   from a nonzero step -- survivors never restart.
2. **Whole-job death**: a second launcher over the same checkpoint
   directory must resume every rank exactly at the last committed step.

Exits nonzero if any rank restarted from scratch or the launcher issued
any data-path operation.  Used by scripts/tier1.sh's SPMD smoke lane.
"""

import os
import signal
import sys
import tempfile
import time

NRANKS = 2
STEPS_1 = 6   # first job: killed partway, finishes after respawn
STEPS_2 = 10  # second job: must resume at step 6, not step 0


def _opts(steps: int, ckpt_dir: str) -> dict:
    return {"arch": "internlm2-1.8b", "smoke": True, "steps": steps,
            "batch": 2, "seq": 32, "microbatches": 1, "lr": 3e-4,
            "ckpt_dir": ckpt_dir, "ckpt_every": 2, "mode": "fused",
            "compression": False, "probe_interval": 0.3}


def main() -> None:
    from repro.core.transport.spmd import SpmdLauncher
    from repro.launch.train import _spmd_entry

    d = tempfile.mkdtemp(prefix="spmd-train-")
    victim = 1

    # -- phase 1: kill one rank after its first checkpoint, respawn -------
    launcher = SpmdLauncher(NRANKS, _spmd_entry, (_opts(STEPS_1, d),))
    try:
        marker = os.path.join(d, f"manifest.r{victim}.json")
        deadline = time.monotonic() + 180
        while not os.path.exists(marker):
            if time.monotonic() > deadline:
                raise SystemExit("victim rank never committed a checkpoint")
            time.sleep(0.1)
        os.kill(launcher._procs[victim].pid, signal.SIGKILL)
        print(f"killed rank {victim} after its first checkpoint",
              flush=True)
        while launcher.probe(victim):
            time.sleep(0.05)
        launcher.rebuild_rank(victim)
        results = sorted(launcher.wait(timeout=240),
                         key=lambda r: r["rank"])
        resumed = results[victim]["resumed_from"]
        assert resumed is not None and resumed > 0, \
            f"respawned rank restarted from scratch: {results[victim]}"
        assert launcher.data_ops() == 0, "launcher issued data-path ops"
        print(f"rank {victim} resumed from step {resumed} after SIGKILL",
              flush=True)
    finally:
        launcher.shutdown()

    # -- phase 2: whole-job restart resumes every rank exactly ------------
    launcher = SpmdLauncher(NRANKS, _spmd_entry, (_opts(STEPS_2, d),))
    try:
        results = sorted(launcher.wait(timeout=240),
                         key=lambda r: r["rank"])
        for res in results:
            assert res["resumed_from"] == STEPS_1, \
                f"rank {res['rank']} resumed at {res['resumed_from']}, " \
                f"expected {STEPS_1}"
        assert launcher.data_ops() == 0, "launcher issued data-path ops"
        print(f"whole-job restart: all {NRANKS} ranks resumed exactly at "
              f"step {STEPS_1}", flush=True)
    finally:
        launcher.shutdown()
    print("spmd_train_resume: PASS", flush=True)


if __name__ == "__main__":
    try:
        import multiprocessing.shared_memory  # noqa: F401
    except ImportError:
        print("spmd_train_resume: SKIP (no multiprocessing.shared_memory)")
        sys.exit(0)
    main()
