"""Kill-and-rebuild smoke: a replicated DHT keeps serving through rank death.

The resilience subsystem's acceptance path end to end: a
``storage_alloc_replication=2`` DHT takes traffic, one replica-holding
worker is SIGKILLed mid-run (under ``REPRO_TRANSPORT=mp``; a simulated
``mark_dead`` otherwise, so the script also runs in-process), and the
table must

* report the rank dead via ``Transport.probe`` / ``FailureDetector``,
* keep serving reads AND writes through transparent failover with zero
  lost *synced* data,
* rebuild the lost partition bit-exact from the replicas onto a
  respawned worker (``comm.rebuild_rank``), and come back clean.

Run:  PYTHONPATH=src python examples/replicated_failover.py
      REPRO_TRANSPORT=mp REPRO_NRANKS=4 PYTHONPATH=src \
          python examples/replicated_failover.py
(The ``__main__`` guard keeps this spawn-safe: mp workers re-import it.)
"""

import tempfile
import time

import numpy as np

from repro.core import Communicator, DistributedHashTable, FailureDetector

LV = 1 << 10
N_KEYS = 300
VICTIM = 1


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="repro_failover_")
    comm = Communicator.from_env(4)
    real_kill = comm.transport.kind in ("mp", "tcp")
    print(f"transport={comm.transport.kind} ranks={comm.size} "
          f"(kill={'SIGKILL' if real_kill else 'simulated'})")

    dht = DistributedHashTable(comm, LV, info={
        "alloc_type": "storage",
        "storage_alloc_filename": f"{tmp}/dht.bin",
    }, replication=2)
    win = dht.win
    assert win.replication == 2, "replication hint was not honored"

    rng = np.random.default_rng(0)
    keys = [int(k) for k in rng.integers(1, 1 << 48, N_KEYS)]
    expect = {}
    for i, k in enumerate(keys):
        dht.insert(k, i, op="replace")
        expect[k] = i
    flushed = dht.sync()  # durability point: every copy now holds the table
    print(f"inserted {len(keys)} keys, synced {flushed >> 10} KiB "
          f"(x{win.replication} copies)")

    # -- kill a replica-holding worker mid-traffic ------------------------
    if real_kill:
        comm.transport.kill_rank(VICTIM)
        assert comm.probe(VICTIM) is False, "probe missed a SIGKILLed rank"
    else:
        comm.mark_dead(VICTIM)
    detector = FailureDetector(comm)
    dead = detector.poll()
    assert VICTIM in dead and VICTIM in detector.monitor.dead(), \
        "FailureDetector/HeartbeatMonitor did not report the rank dead"
    print(f"rank {VICTIM} down (probe+monitor agree); continuing service")

    # -- continued service: zero lost synced data + live writes -----------
    lost = sum(1 for k, v in expect.items() if dht.lookup(k) != v)
    assert lost == 0, f"failover lost {lost} synced keys"
    more = [int(k) for k in rng.integers(1 << 48, 1 << 49, 100)]
    for i, k in enumerate(more):
        dht.insert(k, -i, op="replace")
        expect[k] = -i
    assert all(dht.lookup(k) == v for k, v in expect.items())
    dht.sync()
    print(f"served {len(expect)} lookups + 100 inserts through failover "
          "(0 synced keys lost)")

    # -- respawn + rebuild -------------------------------------------------
    t0 = time.perf_counter()
    copied = comm.rebuild_rank(VICTIM)
    print(f"rebuilt rank {VICTIM} in {time.perf_counter() - t0:.2f}s "
          f"({copied >> 10} KiB reconciled)")
    assert comm.probe(VICTIM), "rebuilt rank did not come back"
    # bit-exact: the rebuilt primary equals the replica that served it
    seg = win.segments[VICTIM]
    rep = win.replica_segs[(VICTIM, 1)]
    a = win.comm.transport.get(seg, 0, seg.size)
    b = win.comm.transport.get(rep, 0, seg.size)
    assert (np.asarray(a) == np.asarray(b)).all(), \
        "rebuilt partition differs from its replica"
    assert all(dht.lookup(k) == v for k, v in expect.items())
    print("post-rebuild verification passed (bit-exact partition, "
          "all keys served by the primary)")

    dht.free()
    comm.close()
    print("done")


if __name__ == "__main__":
    main()
