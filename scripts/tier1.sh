#!/usr/bin/env bash
# Canonical tier-1 gate: install dev requirements (best effort; offline
# containers fall back to the conftest hypothesis stub, which skips the
# property tests instead of failing collection), then run the suite.
#
# Property tests run under a pinned, deadline-disabled hypothesis profile
# ("ci": derandomized example sequence, deadline=None) registered in
# tests/conftest.py, so CI runs are reproducible; override with
# HYPOTHESIS_PROFILE=dev for randomized exploration.
#
# After the suite, a multiprocess smoke lane re-runs the DHT and MapReduce
# examples with ranks as real worker processes (REPRO_TRANSPORT=mp): spawn
# start method (safe under threaded parents), bounded by a timeout, and
# skipped gracefully where multiprocessing.shared_memory is unavailable.
#
# A tcp smoke lane runs the inter-host transport on loopback (2x: the
# replicated SIGKILL-failover example and the enforced aggregated
# small-op speedup gate on the tcp wire); skipped gracefully where
# sockets are restricted.
#
# An SPMD smoke lane then runs real training with every rank as its own
# origin (the repro.launch.train --spmd path): one rank is SIGKILLed
# mid-run and must resume exactly from its own checkpoint after respawn,
# and a whole-job restart must resume every rank at the last committed
# step.  Skipped gracefully without shared_memory or jax.
#
# Usage: scripts/tier1.sh [extra pytest args...]
#   TIER1_QUICK=1 scripts/tier1.sh    # exclude @pytest.mark.slow stress tests
#   TIER1_NO_MP=1 scripts/tier1.sh    # skip the multiprocess smoke lane
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    pip install -r requirements-dev.txt >/dev/null 2>&1 \
        || echo "tier1: could not install dev requirements;" \
                "property tests will be skipped (conftest stub)" >&2
fi

# -- lint lane ----------------------------------------------------------------
# Static gates run BEFORE the suite: a broken invariant should fail in
# seconds, not after 400 tests.  ruff/mypy are baseline hygiene
# (configured in pyproject.toml, pinned in requirements-dev.txt) and are
# skipped gracefully where the tools are not installed; the repo's own
# RMA epoch linter (repro.analysis.rmalint) is pure stdlib and therefore
# ALWAYS enforced -- `--strict` fails the gate on warnings too.
echo "tier1: lint lane (ruff + mypy + rmalint --strict)" >&2
if python -c "import ruff" >/dev/null 2>&1 || command -v ruff >/dev/null 2>&1
then
    ruff check src tests examples benchmarks
else
    echo "tier1: ruff unavailable -- skipping (rmalint still enforced)" >&2
fi
if python -c "import mypy" >/dev/null 2>&1; then
    python -m mypy --config-file pyproject.toml src/repro/analysis
else
    echo "tier1: mypy unavailable -- skipping (rmalint still enforced)" >&2
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.analysis.rmalint --strict

export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-ci}"
echo "tier1: hypothesis profile=${HYPOTHESIS_PROFILE}" \
     "(ci = derandomized, deadline disabled)" >&2

MARKER_ARGS=()
if [[ "${TIER1_QUICK:-0}" == "1" ]]; then
    echo "tier1: quick mode -- excluding slow stress tests (-m 'not slow')" >&2
    MARKER_ARGS=(-m "not slow")
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q ${MARKER_ARGS+"${MARKER_ARGS[@]}"} "$@"

# -- sanitizer smoke lane -----------------------------------------------------
# Re-run the transport conformance suite with the runtime window
# sanitizer armed (REPRO_SANITIZE=1 wraps every built backend in
# repro.analysis.WindowSanitizer): the whole inproc+mp+tcp matrix must
# complete with zero findings -- a SanitizerError fails the suite.  The
# suite's own HAVE_SHM/HAVE_LOOPBACK gates keep this lane graceful where
# mp/tcp are unavailable.
echo "tier1: sanitizer smoke lane (REPRO_SANITIZE=1, transport" \
     "conformance)" >&2
env REPRO_SANITIZE=1 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q tests/test_transport.py

# -- multiprocess smoke lane --------------------------------------------------
if [[ "${TIER1_NO_MP:-0}" == "1" ]]; then
    echo "tier1: TIER1_NO_MP=1 -- skipping multiprocess smoke lane" >&2
elif ! python -c "import multiprocessing.shared_memory" >/dev/null 2>&1; then
    echo "tier1: multiprocessing.shared_memory unavailable --" \
         "skipping multiprocess smoke lane" >&2
else
    echo "tier1: multiprocess smoke lane (REPRO_TRANSPORT=mp, 4 ranks)" >&2
    MP_ENV=(env REPRO_TRANSPORT=mp REPRO_NRANKS=4 REPRO_MP_START=spawn
            PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}")
    timeout 300 "${MP_ENV[@]}" python examples/mapreduce_wordcount.py
    timeout 300 "${MP_ENV[@]}" python examples/out_of_core_dht.py
    # the async-vs-blocking overlap gate, cross-process (enforced: exit 1
    # below the ratio)
    timeout 300 "${MP_ENV[@]}" python -m benchmarks.async_win \
        --transport mp --min-speedup 1.5
    # small-op latency lane, cross-process (enforced: 8-byte put/get under
    # the us/op ceiling on both allocation kinds, and the aggregated rput
    # train must beat the blocking path by the configured speedup on
    # storage windows -- request aggregation amortizing round trips)
    timeout 300 "${MP_ENV[@]}" python -m benchmarks.imb_rma \
        --transport mp --smallop-only
    # compressed-sync wire lane (enforced: the staged-span flush with the
    # codec forced on must cross the control channel at <=50% of the raw
    # path's bytes on compressible dirty pages, and incompressible noise
    # must take the raw fallback at <=1.05x logical) -- jax-free
    timeout 300 "${MP_ENV[@]}" python -m benchmarks.selective_sync \
        --transport mp --codec-only
    # masked device-sync gate, cross-process: at 8% dirty blocks the
    # selective path (one masked span-write message per rank) must write
    # <=15% of the full-sync bytes, and the fused diff+pack path must move
    # all changed bytes in ONE device->host transfer per shard set (the
    # suite's asserts enforce: exit 1).  The device diff needs jax
    # (repro.kernels); skip gracefully without it -- the codec lane above
    # keeps the wire gate enforced either way.
    if python -c "import jax" >/dev/null 2>&1; then
        timeout 300 "${MP_ENV[@]}" python -m benchmarks.selective_sync \
            --transport mp
    else
        echo "tier1: jax unavailable -- skipping mp selective-sync gate" >&2
    fi
    # kill-and-rebuild smoke (resilience subsystem): SIGKILL a
    # replica-holding worker mid-traffic, assert continued DHT service via
    # failover (zero lost synced data) and a bit-exact respawn+rebuild
    timeout 300 "${MP_ENV[@]}" python examples/replicated_failover.py
fi

# -- tcp smoke lane -----------------------------------------------------------
# The inter-host transport on loopback: every primitive crosses real
# framed TCP sockets.  Two enforced pieces: (a) replicated failover --
# SIGKILL one rank mid-traffic, probe reports it dead, DHT service
# continues via replicas with zero lost synced data, respawn rebuilds
# bit-exact (examples/replicated_failover.py asserts: exit 1); (b) the
# aggregated small-op speedup gate on the tcp wire (batched rput trains
# must beat the blocking path by the configured factor).  Skipped
# gracefully where loopback sockets are restricted.
if [[ "${TIER1_NO_MP:-0}" == "1" ]]; then
    echo "tier1: TIER1_NO_MP=1 -- skipping tcp smoke lane" >&2
elif ! python - >/dev/null 2>&1 <<'PY'
import socket
srv = socket.create_server(("127.0.0.1", 0))
srv.close()
PY
then
    echo "tier1: loopback sockets unavailable -- skipping tcp smoke lane" >&2
else
    echo "tier1: tcp smoke lane (REPRO_TRANSPORT=tcp, loopback," \
         "SIGKILL failover + small-op gate)" >&2
    TCP_ENV=(env REPRO_TRANSPORT=tcp REPRO_NRANKS=4
             PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}")
    timeout 300 "${TCP_ENV[@]}" python examples/replicated_failover.py
    timeout 300 "${TCP_ENV[@]}" python -m benchmarks.imb_rma \
        --transport tcp --smallop-only
fi

# -- SPMD smoke lane ----------------------------------------------------------
if [[ "${TIER1_NO_MP:-0}" == "1" ]]; then
    echo "tier1: TIER1_NO_MP=1 -- skipping SPMD smoke lane" >&2
elif ! python -c "import multiprocessing.shared_memory" >/dev/null 2>&1; then
    echo "tier1: multiprocessing.shared_memory unavailable --" \
         "skipping SPMD smoke lane" >&2
elif ! python -c "import jax" >/dev/null 2>&1; then
    echo "tier1: jax unavailable -- skipping SPMD smoke lane" >&2
else
    echo "tier1: SPMD smoke lane (2 application ranks, mid-run SIGKILL," \
         "exact resume)" >&2
    timeout 500 env JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}" \
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python examples/spmd_train_resume.py
fi
