#!/usr/bin/env bash
# Canonical tier-1 gate: install dev requirements (best effort; offline
# containers fall back to the conftest hypothesis stub, which skips the
# property tests instead of failing collection), then run the suite.
#
# Usage: scripts/tier1.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    pip install -r requirements-dev.txt >/dev/null 2>&1 \
        || echo "tier1: could not install dev requirements;" \
                "property tests will be skipped (conftest stub)" >&2
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
