#!/usr/bin/env bash
# Canonical tier-1 gate: install dev requirements (best effort; offline
# containers fall back to the conftest hypothesis stub, which skips the
# property tests instead of failing collection), then run the suite.
#
# Property tests run under a pinned, deadline-disabled hypothesis profile
# ("ci": derandomized example sequence, deadline=None) registered in
# tests/conftest.py, so CI runs are reproducible; override with
# HYPOTHESIS_PROFILE=dev for randomized exploration.
#
# Usage: scripts/tier1.sh [extra pytest args...]
#   TIER1_QUICK=1 scripts/tier1.sh    # exclude @pytest.mark.slow stress tests
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    pip install -r requirements-dev.txt >/dev/null 2>&1 \
        || echo "tier1: could not install dev requirements;" \
                "property tests will be skipped (conftest stub)" >&2
fi

export HYPOTHESIS_PROFILE="${HYPOTHESIS_PROFILE:-ci}"
echo "tier1: hypothesis profile=${HYPOTHESIS_PROFILE}" \
     "(ci = derandomized, deadline disabled)" >&2

MARKER_ARGS=()
if [[ "${TIER1_QUICK:-0}" == "1" ]]; then
    echo "tier1: quick mode -- excluding slow stress tests (-m 'not slow')" >&2
    MARKER_ARGS=(-m "not slow")
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q ${MARKER_ARGS+"${MARKER_ARGS[@]}"} "$@"
